//! The model-update **application path** — the compute half of a
//! learner round trip, shared by the single-cloudlet
//! [`crate::coordinator::Trainer`] and the cluster-level
//! [`crate::cluster::ParamServer`].
//!
//! Both callers speak the same sequence: gather a batch into padded
//! tensor chunks, run `τ` local full-batch SGD iterations through the
//! engine's [`crate::backend::Backend`] (`grad_step` calls in the exact
//! AOT-artifact contract), and evaluate `eval_batch` sums over an index
//! set. Keeping these free functions in one module is what pins the
//! 1-shard ParamServer ≡ Trainer bit-for-bit equivalence
//! (`rust/tests/cluster_global.rs`): the two paths cannot drift apart,
//! because they *are* one path.

use std::sync::Arc;

use crate::backend::{Call, Function};
use crate::compute::ComputePool;
use crate::coordinator::ParamSet;
use crate::dataset::SyntheticDataset;
use crate::models::ModelSpec;
use crate::runtime::{BackendChoice, Engine, EngineHandle, Manifest, Tensor};

/// Start an execution engine for `model` honoring the backend choice:
/// `Auto` picks PJRT only when the artifacts cover both functions the
/// training path executes (`grad_step` + `eval_batch` at the model's
/// exact layer widths), the hermetic native executor otherwise. A
/// forced PJRT engine with non-covering artifacts errors truthfully
/// instead of asserting later in chunk planning.
///
/// The native engine submits its matmul tiles to the process-wide
/// shared compute pool (`MEL_THREADS` / `--compute-threads`), so many
/// engines — the cluster spins up one per shard replay — share the
/// host's cores. [`start_engine_pooled`] pins a dedicated pool size.
pub fn start_engine(
    model: &ModelSpec,
    choice: BackendChoice,
    artifact_dir: &str,
) -> anyhow::Result<Engine> {
    start_engine_pooled(model, choice, artifact_dir, 0)
}

/// [`start_engine`] with an explicit native compute-thread count:
/// `0` = the shared pool (the default everywhere), `n > 0` = a pool of
/// exactly `n` threads dedicated to this engine. Results are
/// bit-for-bit identical either way — the knob trades isolation against
/// sharing, never numerics.
pub fn start_engine_pooled(
    model: &ModelSpec,
    choice: BackendChoice,
    artifact_dir: &str,
    compute_threads: usize,
) -> anyhow::Result<Engine> {
    let covered = |man: &Manifest| {
        ["grad_step", "eval_batch"]
            .iter()
            .all(|f| !man.buckets_for(&model.name, f, &model.layers).is_empty())
    };
    // dedicated pools are built lazily, only where a native backend
    // actually materializes — a PJRT pick must not spawn-and-discard
    // worker threads
    let engine = match choice {
        BackendChoice::Auto => Engine::start_auto_pooled(
            artifact_dir,
            &covered,
            (compute_threads > 0).then_some(compute_threads),
        ),
        BackendChoice::Native if compute_threads > 0 => {
            Engine::start_native_with_pool(Arc::new(ComputePool::new(compute_threads)))
        }
        c => {
            if compute_threads > 0 {
                log::warn!(
                    "compute_threads={compute_threads} applies to the native backend only; \
                     ignored for the pjrt engine"
                );
            }
            Engine::start_with(c, artifact_dir)?
        }
    };
    if let Some(man) = engine.manifest() {
        // only reachable on a forced --backend pjrt
        anyhow::ensure!(
            covered(man),
            "artifacts missing grad_step/eval_batch for arch {:?} with layers {:?}; \
             run `make artifacts` (or use the native backend)",
            model.name,
            model.layers
        );
    }
    Ok(engine)
}

/// Pad `idx[lo..hi]` features/labels into a `bucket`-row tensor triple.
/// With `bucket == idx.len()` (the native backend) no padding happens.
pub fn padded_chunk(ds: &SyntheticDataset, idx: &[usize], bucket: usize) -> (Tensor, Tensor, Tensor) {
    let f = ds.spec.features;
    let n = idx.len();
    let (mut x, mut y) = ds.gather_f32(idx);
    x.resize(bucket * f, 0.0);
    y.resize(bucket, 0);
    let mut mask = vec![1.0f32; n];
    mask.resize(bucket, 0.0);
    (
        Tensor::f32(vec![bucket, f], x),
        Tensor::i32(vec![bucket], y),
        Tensor::f32(vec![bucket], mask),
    )
}

/// Chunking strategy for `n` samples: the manifest's bucketed plan for
/// PJRT engines (layer-exact, matching the backend's artifact
/// resolution), a single exact-size chunk for the native backend.
pub fn plan_chunks(man: Option<&Manifest>, call: &Call, n: usize) -> Vec<(usize, usize, usize)> {
    match man {
        Some(m) => chunk_plan(m, &call.arch, call.function.name(), &call.layers, n),
        None => vec![(0, n, n)],
    }
}

/// One learner's τ local iterations of full-batch SGD over its batch,
/// accumulating masked gradient chunks through the backend.
///
/// On the native single-chunk path (no manifest → `plan_chunks` is one
/// exact chunk) a `GradStep` call is upgraded to [`Function::FusedStep`]:
/// the backend applies the SGD update in-call, so the per-iteration
/// gradient round trip and the zero/accumulate/apply passes disappear.
/// The fused arithmetic is bit-for-bit the unfused path's
/// (`rust/tests/backend_native.rs`), so every equivalence downstream —
/// trainer ≡ 1-shard cluster ≡ ParamServer replay — is unaffected. The
/// PJRT/bucketed path (and multi-chunk plans, whose gradients must
/// accumulate before one apply) keeps the unfused loop.
#[allow(clippy::too_many_arguments)]
pub fn local_training(
    handle: &EngineHandle,
    man: Option<&Manifest>,
    call: &Call,
    local: &mut ParamSet,
    ds: &SyntheticDataset,
    idx: &[usize],
    tau: u64,
    lr: f32,
) -> anyhow::Result<()> {
    // Wall-clock cost of one learner's full local round (τ iterations);
    // a no-op unless tracing is enabled.
    let _train_span = crate::trace::wall_span(
        "train",
        "local_training",
        crate::trace::current_shard(),
        0,
        &[("tau", tau as f64), ("n", idx.len() as f64)],
    );
    let plan = plan_chunks(man, call, idx.len());
    if man.is_none() && call.function == Function::GradStep && plan.len() == 1 {
        let fused = Call { function: Function::FusedStep, ..call.clone() };
        let (lo, hi, bucket) = plan[0];
        // the batch tensors are iteration-invariant: build them once
        let (x, y, mask) = padded_chunk(ds, &idx[lo..hi], bucket);
        for _ in 0..tau {
            let mut inputs = local.tensors.clone();
            inputs.push(x.clone());
            inputs.push(y.clone());
            inputs.push(mask.clone());
            inputs.push(Tensor::scalar_f32(lr));
            let out = handle.call(&fused, inputs)?;
            anyhow::ensure!(
                out.len() == local.tensors.len() + 2,
                "fused_step returned {} tensors",
                out.len()
            );
            for (p, np) in local.tensors.iter_mut().zip(out) {
                *p = np;
            }
        }
        return Ok(());
    }
    for _ in 0..tau {
        let mut grad_acc = local.zeros_like();
        let mut weight = 0.0f32;
        for (lo, hi, bucket) in plan_chunks(man, call, idx.len()) {
            let (x, y, mask) = padded_chunk(ds, &idx[lo..hi], bucket);
            let mut inputs = local.tensors.clone();
            inputs.push(x);
            inputs.push(y);
            inputs.push(mask);
            let out = handle.call(call, inputs)?;
            anyhow::ensure!(
                out.len() == local.tensors.len() + 2,
                "grad_step returned {} tensors",
                out.len()
            );
            for (acc, g) in grad_acc.iter_mut().zip(&out[..local.tensors.len()]) {
                acc.axpy(1.0, g);
            }
            weight += out[local.tensors.len() + 1].scalar();
        }
        local.sgd_apply(&grad_acc, lr, weight);
    }
    Ok(())
}

/// Evaluate loss/accuracy sums over an index set.
pub fn eval_batches(
    handle: &EngineHandle,
    man: Option<&Manifest>,
    call: &Call,
    params: &ParamSet,
    ds: &SyntheticDataset,
    idx: &[usize],
) -> anyhow::Result<(f64, f64, f64)> {
    let mut loss_sum = 0.0f64;
    let mut correct = 0.0f64;
    let mut weight = 0.0f64;
    for (lo, hi, bucket) in plan_chunks(man, call, idx.len()) {
        let (x, y, mask) = padded_chunk(ds, &idx[lo..hi], bucket);
        let mut inputs = params.tensors.clone();
        inputs.push(x);
        inputs.push(y);
        inputs.push(mask);
        let out = handle.call(call, inputs)?;
        anyhow::ensure!(out.len() == 3, "eval_batch returned {} tensors", out.len());
        loss_sum += out[0].scalar() as f64;
        correct += out[1].scalar() as f64;
        weight += out[2].scalar() as f64;
    }
    Ok((loss_sum, correct, weight))
}

/// Split `n` samples into (lo, hi, bucket) chunks using the buckets
/// lowered for exactly `layers`: big chunks use the largest bucket; the
/// tail uses the smallest bucket that fits (minimizing padding waste).
pub fn chunk_plan(
    man: &Manifest,
    arch: &str,
    function: &str,
    layers: &[usize],
    n: usize,
) -> Vec<(usize, usize, usize)> {
    let buckets = man.buckets_for(arch, function, layers);
    assert!(!buckets.is_empty(), "no buckets for {arch}/{function} with layers {layers:?}");
    // mel-lint: allow(R1) — the assert one line above guarantees a non-empty bucket list
    let largest = *buckets.last().expect("non-empty buckets");
    let mut plan = Vec::new();
    let mut lo = 0;
    while lo < n {
        let remaining = n - lo;
        let bucket = if remaining >= largest {
            largest
        } else {
            buckets.iter().copied().find(|&b| b >= remaining).unwrap_or(largest)
        };
        let take = remaining.min(bucket);
        plan.push((lo, lo + take, bucket));
        lo += take;
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::Function;

    fn fake_man() -> Manifest {
        // hand-construct a manifest with buckets {8, 32}
        Manifest {
            dir: "/tmp".into(),
            artifacts: [8usize, 32]
                .iter()
                .map(|&b| crate::runtime::ArtifactMeta {
                    name: format!("toy_grad_step_b{b}"),
                    file: "/dev/null".into(),
                    arch: "toy".into(),
                    function: "grad_step".into(),
                    bucket: b,
                    layers: vec![4, 2],
                    param_tensors: 2,
                    inputs: vec![],
                    outputs: vec![],
                    sha256: String::new(),
                })
                .collect(),
        }
    }

    #[test]
    fn chunk_plan_covers_exactly_once() {
        let man = fake_man();
        for n in [1usize, 7, 8, 9, 31, 32, 33, 100, 257] {
            let plan = chunk_plan(&man, "toy", "grad_step", &[4, 2], n);
            let mut covered = 0;
            let mut prev_hi = 0;
            for (lo, hi, bucket) in &plan {
                assert_eq!(*lo, prev_hi);
                assert!(hi - lo <= *bucket);
                covered += hi - lo;
                prev_hi = *hi;
            }
            assert_eq!(covered, n, "n={n} plan={plan:?}");
        }
    }

    #[test]
    fn chunk_plan_minimizes_tail_padding() {
        let man = fake_man();
        // 40 = 32 + 8: the 8-tail must use the small bucket
        let plan = chunk_plan(&man, "toy", "grad_step", &[4, 2], 40);
        assert_eq!(plan, vec![(0, 32, 32), (32, 40, 8)]);
        // 5 → single small bucket
        assert_eq!(chunk_plan(&man, "toy", "grad_step", &[4, 2], 5), vec![(0, 5, 8)]);
    }

    #[test]
    fn native_plan_is_one_exact_chunk() {
        let call = Call::new(Function::GradStep, "toy", &[4, 2]);
        // no manifest (native backend): a single chunk, no padding
        assert_eq!(plan_chunks(None, &call, 37), vec![(0, 37, 37)]);
        // with a manifest the bucketed plan applies, layer-exact
        let man = fake_man();
        assert_eq!(plan_chunks(Some(&man), &call, 40), vec![(0, 32, 32), (32, 40, 8)]);
        // a call for different layers must not see those buckets
        let other = Call::new(Function::GradStep, "toy", &[4, 3, 2]);
        assert!(man.buckets_for("toy", "grad_step", &other.layers).is_empty());
    }

    #[test]
    fn padded_chunk_masks_tail() {
        let spec = crate::dataset::DatasetSpec {
            name: "t".into(),
            total_samples: 10,
            features: 4,
            classes: 2,
            precision_bits: 8,
        };
        let ds = SyntheticDataset::generate(&spec, 10, 1);
        let (x, y, m) = padded_chunk(&ds, &[0, 1, 2], 8);
        assert_eq!(x.dims, vec![8, 4]);
        assert_eq!(y.dims, vec![8]);
        assert_eq!(m.as_f32(), &[1., 1., 1., 0., 0., 0., 0., 0.]);
        // padded feature rows are zero
        assert!(x.as_f32()[3 * 4..].iter().all(|&v| v == 0.0));
        // exact-size chunk (native path) needs no padding
        let (x, _, m) = padded_chunk(&ds, &[0, 1, 2], 3);
        assert_eq!(x.dims, vec![3, 4]);
        assert_eq!(m.as_f32(), &[1., 1., 1.]);
    }

    #[test]
    fn fused_local_training_matches_the_unfused_replay_bit_for_bit() {
        if crate::runtime::pjrt_available() {
            return;
        }
        let spec = crate::dataset::DatasetSpec {
            name: "t".into(),
            total_samples: 64,
            features: 12,
            classes: 3,
            precision_bits: 32,
        };
        let ds = SyntheticDataset::generate(&spec, 64, 9);
        let layers = [12usize, 16, 3];
        let idx: Vec<usize> = (0..40).collect();
        let (tau, lr) = (5u64, 0.1f32);
        let engine =
            start_engine(&ModelSpec::pedestrian(), BackendChoice::Native, "artifacts").unwrap();
        let call = Call::new(Function::GradStep, "toy", &layers);
        // fused: local_training's native single-chunk fast path
        let mut fused = ParamSet::init(&layers, 3);
        local_training(&engine.handle(), None, &call, &mut fused, &ds, &idx, tau, lr).unwrap();
        // unfused replay: explicit grad_step + accumulate + sgd_apply
        let mut unfused = ParamSet::init(&layers, 3);
        for _ in 0..tau {
            let (x, y, mask) = padded_chunk(&ds, &idx, idx.len());
            let mut inputs = unfused.tensors.clone();
            inputs.extend([x, y, mask]);
            let out = engine.handle().call(&call, inputs).unwrap();
            let np = unfused.tensors.len();
            let mut acc = unfused.zeros_like();
            for (a, g) in acc.iter_mut().zip(&out[..np]) {
                a.axpy(1.0, g);
            }
            let weight = out[np + 1].scalar();
            unfused.sgd_apply(&acc, lr, weight);
        }
        for (a, b) in fused.tensors.iter().zip(&unfused.tensors) {
            assert_eq!(a.dims, b.dims);
            for (x, y) in a.as_f32().iter().zip(b.as_f32()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn start_engine_auto_is_native_without_artifacts() {
        if crate::runtime::pjrt_available() {
            return;
        }
        let engine =
            start_engine(&ModelSpec::pedestrian(), BackendChoice::Auto, "artifacts").unwrap();
        assert_eq!(engine.kind(), crate::runtime::BackendKind::Native);
    }

    #[test]
    fn start_engine_pooled_pins_a_dedicated_pool() {
        if crate::runtime::pjrt_available() {
            return;
        }
        // both a forced-native and an auto engine accept the knob, and
        // the pinned engine still executes calls end to end
        for choice in [BackendChoice::Native, BackendChoice::Auto] {
            let engine =
                start_engine_pooled(&ModelSpec::pedestrian(), choice, "artifacts", 2).unwrap();
            assert_eq!(engine.kind(), crate::runtime::BackendKind::Native);
            let layers = [3usize, 4, 2];
            let call = Call::new(Function::GradStep, "toy", &layers);
            let inputs = crate::testkit::zero_param_mlp_inputs(&layers, 5, 5);
            let out = engine.handle().call(&call, inputs).unwrap();
            assert_eq!(out.len(), 6);
            assert_eq!(out[5].scalar(), 5.0);
        }
    }
}
