//! Property tests over the full scenario → problem → allocation chain,
//! using the in-crate testkit (generators + shrinking). These are the
//! paper's structural guarantees, checked on random cloudlets rather
//! than the hand-built fixtures of the unit tests.

use mel::alloc::exact::ExactAllocator;
use mel::alloc::Policy;
use mel::scenario::{CloudletConfig, Scenario};
use mel::testkit::*;

/// Generator: (task index, K, T-seconds, seed).
fn scenario_gen() -> impl Gen<(usize, usize, f64, u64)> {
    struct G;
    impl Gen<(usize, usize, f64, u64)> for G {
        fn gen(&self, rng: &mut mel::util::rng::Pcg64) -> (usize, usize, f64, u64) {
            use mel::util::rng::Rng;
            (
                rng.below(2) as usize,
                rng.range_u64(2, 40) as usize,
                rng.uniform(15.0, 150.0),
                rng.next_u64(),
            )
        }
        fn shrink(&self, v: &(usize, usize, f64, u64)) -> Vec<(usize, usize, f64, u64)> {
            let mut out = Vec::new();
            if v.1 > 2 {
                out.push((v.0, v.1 / 2, v.2, v.3));
                out.push((v.0, v.1 - 1, v.2, v.3));
            }
            out
        }
    }
    G
}

fn build(task_i: usize, k: usize, seed: u64) -> Scenario {
    let task = if task_i == 0 { "pedestrian" } else { "mnist" };
    Scenario::random_cloudlet(&CloudletConfig::by_task(task, k).unwrap(), seed)
}

#[test]
fn every_policy_returns_feasible_allocations() {
    forall("feasible allocations", &scenario_gen(), |&(ti, k, t, seed)| {
        let p = build(ti, k, seed).problem(t);
        Policy::all().iter().all(|policy| match policy.allocator().allocate(&p) {
            Ok(a) => {
                a.is_feasible(&p)
                    && a.batches.iter().sum::<usize>() == p.total_samples
                    && a.makespan(&p) <= t + 1e-6
            }
            Err(_) => true, // infeasible scenarios may error
        })
    });
}

#[test]
fn adaptive_policies_agree_and_are_optimal() {
    forall("adaptive == exact optimum", &scenario_gen(), |&(ti, k, t, seed)| {
        let p = build(ti, k, seed).problem(t);
        let exact = ExactAllocator::optimal_tau(&p);
        [Policy::Analytical, Policy::UbSai, Policy::Numerical].iter().all(|policy| {
            match (policy.allocator().allocate(&p), exact) {
                (Ok(a), Some(opt)) => a.tau == opt,
                (Err(_), None) => true,
                // relaxed-feasible but τ<1, or vice versa — must not happen
                _ => false,
            }
        })
    });
}

#[test]
fn exact_tau_upper_bounds_heuristics_and_constraints_hold() {
    // ISSUE satellite: for random feasible problems, the exact integer
    // optimum τ* dominates what analytical and UB-SAI return, and every
    // returned allocation satisfies the eq. (13) deadline constraint
    // C2·τ_k·d_k + C1·d_k + C0 ≤ T + TIME_EPS per learner.
    use mel::alloc::TIME_EPS;
    forall("exact τ* ≥ heuristic τ; constraints", &scenario_gen(), |&(ti, k, t, seed)| {
        let p = build(ti, k, seed).problem(t);
        let exact = ExactAllocator::optimal_tau(&p);
        [Policy::Analytical, Policy::UbSai].iter().all(|policy| {
            match policy.allocator().allocate(&p) {
                Ok(a) => {
                    let bounded = match exact {
                        Some(opt) => opt >= a.tau,
                        None => false, // solver feasible ⇒ exact feasible
                    };
                    bounded
                        && a.batches.iter().zip(&p.coeffs).enumerate().all(|(i, (&d, c))| {
                            d == 0
                                || c.c2 * a.tau_for(i) as f64 * d as f64
                                    + c.c1 * d as f64
                                    + c.c0
                                    <= t + TIME_EPS
                        })
                }
                Err(_) => true, // infeasible scenarios may error
            }
        })
    });
}

#[test]
fn async_eta_per_learner_taus_dominate_sync_eta() {
    // per-learner τ_k generalization: each learner's async lease count
    // is ≥ the barrier τ, feasible under its own deadline
    forall("async τ_k ≥ sync τ", &scenario_gen(), |&(ti, k, t, seed)| {
        let p = build(ti, k, seed).problem(t);
        match (
            Policy::Eta.allocator().allocate(&p),
            Policy::AsyncEta.allocator().allocate(&p),
        ) {
            (Ok(sync), Ok(asy)) => {
                asy.is_feasible(&p)
                    && asy.batches == sync.batches
                    && (0..p.k()).all(|i| asy.tau_for(i) >= sync.tau)
                    && asy.tau == sync.tau
            }
            (Err(_), Err(_)) => true,
            // same equal split ⇒ identical feasibility condition
            _ => false,
        }
    });
}

#[test]
fn eta_never_exceeds_adaptive() {
    forall("ETA ≤ adaptive", &scenario_gen(), |&(ti, k, t, seed)| {
        let p = build(ti, k, seed).problem(t);
        match (
            Policy::Eta.allocator().allocate(&p),
            Policy::Analytical.allocator().allocate(&p),
        ) {
            (Ok(e), Ok(a)) => e.tau <= a.tau,
            (Ok(_), Err(_)) => false, // ETA feasible ⇒ adaptive feasible
            _ => true,
        }
    });
}

#[test]
fn tau_monotone_in_t() {
    forall("τ monotone in T", &scenario_gen(), |&(ti, k, t, seed)| {
        let s = build(ti, k, seed);
        let solve = |tt: f64| {
            Policy::Analytical
                .allocator()
                .allocate(&s.problem(tt))
                .map(|a| a.tau)
                .unwrap_or(0)
        };
        solve(t) <= solve(t * 1.5)
    });
}

#[test]
fn relaxed_tau_upper_bounds_integer_tau() {
    forall("τ* ≥ τ_int", &scenario_gen(), |&(ti, k, t, seed)| {
        let p = build(ti, k, seed).problem(t);
        match Policy::Analytical.allocator().allocate(&p) {
            Ok(a) => a.tau as f64 <= a.relaxed_tau + 1e-9,
            Err(_) => true,
        }
    });
}

#[test]
fn batches_inversely_ordered_by_compute_cost() {
    // slower learners (larger C2) must never get more samples than a
    // uniformly faster learner under the adaptive policy
    forall("slow ⇒ smaller batch", &scenario_gen(), |&(ti, k, t, seed)| {
        let p = build(ti, k, seed).problem(t);
        match Policy::Analytical.allocator().allocate(&p) {
            Ok(a) => {
                for i in 0..p.k() {
                    for j in 0..p.k() {
                        let ci = &p.coeffs[i];
                        let cj = &p.coeffs[j];
                        // i strictly dominated by j in every coefficient
                        if ci.c2 > cj.c2 * 1.001 && ci.c1 >= cj.c1 && ci.c0 >= cj.c0
                            && a.batches[i] > a.batches[j] + 1
                        {
                            return false;
                        }
                    }
                }
                true
            }
            Err(_) => true,
        }
    });
}

#[test]
fn scenario_json_round_trip_preserves_allocation() {
    forall("JSON round trip", &scenario_gen(), |&(ti, k, t, seed)| {
        let s = build(ti, k, seed);
        let text = s.to_json().to_string();
        let back =
            Scenario::from_json(&mel::util::json::Json::parse(&text).unwrap()).unwrap();
        let a1 = Policy::Analytical.allocator().allocate(&s.problem(t));
        let a2 = Policy::Analytical.allocator().allocate(&back.problem(t));
        match (a1, a2) {
            (Ok(x), Ok(y)) => x.tau == y.tau && x.batches == y.batches,
            (Err(_), Err(_)) => true,
            _ => false,
        }
    });
}

#[test]
fn cycle_sim_completion_equals_eq13_everywhere() {
    use mel::sim::CycleSim;
    forall("sim == eq.13", &scenario_gen(), |&(ti, k, t, seed)| {
        let p = build(ti, k, seed).problem(t);
        match Policy::Analytical.allocator().allocate(&p) {
            Ok(a) => {
                let rep = CycleSim::from_problem(&p).run_cycle(&a, false);
                rep.deadline_misses.is_empty()
                    && a.batches.iter().zip(&p.coeffs).enumerate().all(|(i, (&d, c))| {
                        d == 0
                            || (rep.completion[i] - c.time(a.tau as f64, d as f64)).abs()
                                < 1e-9 * t
                    })
            }
            Err(_) => true,
        }
    });
}

#[test]
fn energy_is_positive_and_tau_linear() {
    use mel::energy::{cycle_energy, DEFAULT_KAPPA};
    forall("energy sane", &scenario_gen(), |&(ti, k, t, seed)| {
        let s = build(ti, k, seed);
        let p = s.problem(t);
        match Policy::Analytical.allocator().allocate(&p) {
            Ok(a) => {
                let e = cycle_energy(&s.learners, &s.model, &a, DEFAULT_KAPPA);
                if e.grand_total() <= 0.0 {
                    return false;
                }
                // compute term linear in τ
                let mut a2 = a.clone();
                a2.tau *= 3;
                let e2 = cycle_energy(&s.learners, &s.model, &a2, DEFAULT_KAPPA);
                e.per_learner.iter().zip(&e2.per_learner).all(|(x, y)| {
                    (y.compute_j - 3.0 * x.compute_j).abs() <= 1e-9 * (1.0 + y.compute_j)
                })
            }
            Err(_) => true,
        }
    });
}

#[test]
fn adaptive_enrolment_monotone_on_random_pools() {
    use mel::alloc::selection::subproblem;
    forall("enrolment monotone", &scenario_gen(), |&(ti, k, t, seed)| {
        if k < 3 {
            return true;
        }
        let p = build(ti, k, seed).problem(t);
        let full = Policy::Analytical.allocator().allocate(&p);
        let idx: Vec<usize> = (0..p.k() - 1).collect();
        let part = Policy::Analytical.allocator().allocate(&subproblem(&p, &idx));
        match (full, part) {
            (Ok(f), Ok(s)) => f.tau >= s.tau,
            (Err(_), Ok(_)) => false, // removing a node cannot create feasibility
            _ => true,
        }
    });
}

#[test]
fn ub_sai_start_point_bounded_by_relaxed_optimum() {
    use mel::alloc::heuristic::UbSaiAllocator;
    // eq.(32) is the equal-batch τ — never above the adaptive relaxed τ*
    forall("eq.32 ≤ τ*", &scenario_gen(), |&(ti, k, t, seed)| {
        let p = build(ti, k, seed).problem(t);
        match (UbSaiAllocator::tau_start(&p), mel::alloc::relax::solve(&p)) {
            (Ok(t0), Ok(sol)) => t0 <= sol.tau + 1e-6 * (1.0 + sol.tau),
            _ => true,
        }
    });
}
