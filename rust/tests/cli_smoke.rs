//! CLI smoke tests: run the built `mel` binary end to end.

use std::process::Command;

fn mel(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(env!("CARGO_BIN_EXE_mel"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn mel");
    (
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
        out.status.success(),
    )
}

#[test]
fn help_lists_commands() {
    let (stdout, _, ok) = mel(&[]);
    assert!(ok);
    for cmd in ["solve", "figure", "train", "scenario", "info"] {
        assert!(stdout.contains(cmd), "missing {cmd} in help:\n{stdout}");
    }
}

#[test]
fn solve_all_policies_table() {
    let (stdout, stderr, ok) = mel(&["solve", "--task", "pedestrian", "--k", "10", "--t", "30"]);
    assert!(ok, "stderr: {stderr}");
    for label in ["ETA", "UB-Analytical", "UB-SAI", "Numerical"] {
        assert!(stdout.contains(label), "{stdout}");
    }
    assert!(stdout.contains("K=10"));
}

#[test]
fn solve_single_policy_and_bad_policy() {
    let (stdout, _, ok) = mel(&["solve", "--policy", "eta", "--k", "4"]);
    assert!(ok);
    assert!(stdout.contains("ETA") && !stdout.contains("UB-SAI"));
    let (_, stderr, ok) = mel(&["solve", "--policy", "nonsense"]);
    assert!(!ok);
    assert!(stderr.contains("unknown policy"));
}

#[test]
fn figure_gains_pass() {
    let (stdout, stderr, ok) = mel(&["figure", "gains", "--seed", "42"]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("headline"));
    assert!(!stderr.contains("WARNING"), "claims should hold: {stdout}");
    // every row holds
    assert!(!stdout.contains("| NO"), "{stdout}");
}

#[test]
fn figure_fig2_renders_series() {
    let (stdout, _, ok) = mel(&["figure", "fig2", "--seed", "1"]);
    assert!(ok);
    assert!(stdout.contains("UB-Analytical K=20"));
    assert!(stdout.contains("ETA K=5"));
}

#[test]
fn scenario_json_and_describe() {
    let (stdout, _, ok) = mel(&["scenario", "--task", "mnist", "--k", "4", "--seed", "9"]);
    assert!(ok);
    let v = mel::util::json::Json::parse(&stdout).expect("valid JSON");
    assert_eq!(v.get("learners").unwrap().as_arr().unwrap().len(), 4);
    let (stdout, _, ok) = mel(&["scenario", "--k", "4", "--describe"]);
    assert!(ok);
    assert!(stdout.contains("rate(Mbps)"));
}

#[test]
fn info_runs() {
    let (stdout, _, ok) = mel(&["info"]);
    assert!(ok);
    assert!(stdout.contains("Mobile Edge Learning"));
}

#[test]
fn unknown_command_exits_nonzero() {
    let (_, _, ok) = mel(&["frobnicate"]);
    assert!(!ok);
}

#[test]
fn energy_table_renders() {
    let (stdout, stderr, ok) = mel(&["energy", "--k", "6", "--t", "30"]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("mJ per sample-iter"));
    assert!(stdout.contains("UB-Analytical"));
}

#[test]
fn figure_fig_async_renders() {
    let (stdout, stderr, ok) = mel(&["figure", "figAsync", "--seed", "42"]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("updates sync ETA"));
    assert!(stdout.contains("iters async ETA"));
}

#[test]
fn solve_async_eta_policy() {
    let (stdout, stderr, ok) =
        mel(&["solve", "--policy", "async-eta", "--k", "6", "--t", "30"]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("Async-ETA"), "{stdout}");
}

#[test]
fn figure_fig_e_renders() {
    let (stdout, stderr, ok) = mel(&["figure", "figE"]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("loss_milli adaptive"));
    assert!(stdout.contains("loss_milli ETA"));
}

#[test]
fn malformed_numeric_flags_are_usage_errors_not_panics() {
    // --k expects an integer: proper usage error, nonzero exit, no panic
    let (_, stderr, ok) = mel(&["solve", "--k", "notanint"]);
    assert!(!ok);
    assert!(stderr.contains("--k expects an integer"), "stderr: {stderr}");
    assert!(!stderr.contains("panicked"), "must not panic: {stderr}");
    // float and list flags too
    let (_, stderr, ok) = mel(&["solve", "--t", "3.5.1"]);
    assert!(!ok);
    assert!(stderr.contains("--t expects a number"), "stderr: {stderr}");
    assert!(!stderr.contains("panicked"), "must not panic: {stderr}");
    let (_, stderr, ok) = mel(&["sweep", "--ks", "5,ten"]);
    assert!(!ok);
    assert!(stderr.contains("bad integer"), "stderr: {stderr}");
    assert!(!stderr.contains("panicked"), "must not panic: {stderr}");
}

#[test]
fn figure_fig_cluster_renders() {
    let (stdout, stderr, ok) = mel(&["figure", "figCluster", "--seed", "42"]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("updates churn re-lease"), "{stdout}");
    assert!(stdout.contains("updates sync"), "{stdout}");
}

#[test]
fn sweep_renders_and_writes_csv() {
    let (stdout, stderr, ok) = mel(&[
        "sweep", "--task", "mnist", "--ks", "5,10", "--ts", "60,120", "--policy", "sai",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("gain_vs_eta"));
    // 2 x 2 grid rows plus borders/header
    assert!(stdout.matches('\n').count() >= 8);
}
