//! CLI smoke tests: run the built `mel` binary end to end.

use std::process::Command;

fn mel(args: &[&str]) -> (String, String, bool) {
    let (stdout, stderr, code) = mel_code(args);
    (stdout, stderr, code == Some(0))
}

/// Like [`mel`] but surfaces the exact exit code, for tests pinning the
/// usage-error (2) vs runtime-failure (1) convention.
fn mel_code(args: &[&str]) -> (String, String, Option<i32>) {
    let out = Command::new(env!("CARGO_BIN_EXE_mel"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn mel");
    (
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
        out.status.code(),
    )
}

#[test]
fn help_lists_commands() {
    let (stdout, _, ok) = mel(&[]);
    assert!(ok);
    for cmd in ["solve", "figure", "train", "scenario", "trace", "resume", "lint", "info"] {
        assert!(stdout.contains(cmd), "missing {cmd} in help:\n{stdout}");
    }
}

#[test]
fn solve_all_policies_table() {
    let (stdout, stderr, ok) = mel(&["solve", "--task", "pedestrian", "--k", "10", "--t", "30"]);
    assert!(ok, "stderr: {stderr}");
    for label in ["ETA", "UB-Analytical", "UB-SAI", "Numerical"] {
        assert!(stdout.contains(label), "{stdout}");
    }
    assert!(stdout.contains("K=10"));
}

#[test]
fn solve_single_policy_and_bad_policy() {
    let (stdout, _, ok) = mel(&["solve", "--policy", "eta", "--k", "4"]);
    assert!(ok);
    assert!(stdout.contains("ETA") && !stdout.contains("UB-SAI"));
    let (_, stderr, ok) = mel(&["solve", "--policy", "nonsense"]);
    assert!(!ok);
    assert!(stderr.contains("unknown policy"));
}

#[test]
fn figure_gains_pass() {
    let (stdout, stderr, ok) = mel(&["figure", "gains", "--seed", "42"]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("headline"));
    assert!(!stderr.contains("WARNING"), "claims should hold: {stdout}");
    // every row holds
    assert!(!stdout.contains("| NO"), "{stdout}");
}

#[test]
fn figure_fig2_renders_series() {
    let (stdout, _, ok) = mel(&["figure", "fig2", "--seed", "1"]);
    assert!(ok);
    assert!(stdout.contains("UB-Analytical K=20"));
    assert!(stdout.contains("ETA K=5"));
}

#[test]
fn scenario_json_and_describe() {
    let (stdout, _, ok) = mel(&["scenario", "--task", "mnist", "--k", "4", "--seed", "9"]);
    assert!(ok);
    let v = mel::util::json::Json::parse(&stdout).expect("valid JSON");
    assert_eq!(v.get("learners").unwrap().as_arr().unwrap().len(), 4);
    let (stdout, _, ok) = mel(&["scenario", "--k", "4", "--describe"]);
    assert!(ok);
    assert!(stdout.contains("rate(Mbps)"));
}

#[test]
fn info_runs() {
    let (stdout, _, ok) = mel(&["info"]);
    assert!(ok);
    assert!(stdout.contains("Mobile Edge Learning"));
}

#[test]
fn unknown_command_exits_nonzero() {
    let (_, _, ok) = mel(&["frobnicate"]);
    assert!(!ok);
}

#[test]
fn energy_table_renders() {
    let (stdout, stderr, ok) = mel(&["energy", "--k", "6", "--t", "30"]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("mJ per sample-iter"));
    assert!(stdout.contains("UB-Analytical"));
}

#[test]
fn figure_fig_async_renders() {
    let (stdout, stderr, ok) = mel(&["figure", "figAsync", "--seed", "42"]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("updates sync ETA"));
    assert!(stdout.contains("iters async ETA"));
}

#[test]
fn solve_async_eta_policy() {
    let (stdout, stderr, ok) =
        mel(&["solve", "--policy", "async-eta", "--k", "6", "--t", "30"]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("Async-ETA"), "{stdout}");
}

#[test]
fn figure_fig_e_renders() {
    let (stdout, stderr, ok) = mel(&["figure", "figE"]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("loss_milli adaptive"));
    assert!(stdout.contains("loss_milli ETA"));
}

#[test]
fn malformed_numeric_flags_are_usage_errors_not_panics() {
    // --k expects an integer: proper usage error, nonzero exit, no panic
    let (_, stderr, ok) = mel(&["solve", "--k", "notanint"]);
    assert!(!ok);
    assert!(stderr.contains("--k expects an integer"), "stderr: {stderr}");
    assert!(!stderr.contains("panicked"), "must not panic: {stderr}");
    // float and list flags too
    let (_, stderr, ok) = mel(&["solve", "--t", "3.5.1"]);
    assert!(!ok);
    assert!(stderr.contains("--t expects a number"), "stderr: {stderr}");
    assert!(!stderr.contains("panicked"), "must not panic: {stderr}");
    let (_, stderr, ok) = mel(&["sweep", "--ks", "5,ten"]);
    assert!(!ok);
    assert!(stderr.contains("bad integer"), "stderr: {stderr}");
    assert!(!stderr.contains("panicked"), "must not panic: {stderr}");
    // zero hidden widths are a usage error, not an assert panic
    let (_, stderr, ok) = mel(&["train", "--k", "2", "--d", "32", "--hidden", "16,0"]);
    assert!(!ok);
    assert!(stderr.contains("--hidden widths must be positive"), "stderr: {stderr}");
    assert!(!stderr.contains("panicked"), "must not panic: {stderr}");
}

#[test]
fn precision_bits_flag_validates_range() {
    // out-of-range P_m bit-widths are usage errors (exit 2), never the
    // silent `as u32` truncation that used to corrupt C¹_k/C⁰_k
    for bad in ["0", "65", "4096"] {
        let (_, stderr, ok) = mel(&["solve", "--k", "4", "--precision-bits", bad]);
        assert!(!ok, "--precision-bits {bad} must fail");
        assert!(stderr.contains("1..=64"), "stderr: {stderr}");
        assert!(!stderr.contains("panicked"), "must not panic: {stderr}");
    }
    // malformed values fall through the shared numeric-flag handling
    let (_, stderr, ok) = mel(&["solve", "--k", "4", "--precision-bits", "eight"]);
    assert!(!ok);
    assert!(stderr.contains("--precision-bits expects an integer"), "stderr: {stderr}");
    // an in-range override threads into the generated scenario
    let (stdout, stderr, ok) =
        mel(&["scenario", "--task", "mnist", "--k", "2", "--precision-bits", "16"]);
    assert!(ok, "stderr: {stderr}");
    let v = mel::util::json::Json::parse(&stdout).expect("valid JSON");
    assert_eq!(
        v.get("dataset").unwrap().get("precision_bits").unwrap().as_u64().unwrap(),
        16
    );
}

#[test]
fn compute_threads_flag_sizes_the_pool() {
    // a pinned pool trains end to end through the native backend
    let (stdout, stderr, ok) = mel(&[
        "train", "--task", "pedestrian", "--k", "2", "--t", "2", "--d", "96", "--cycles", "1",
        "--hidden", "8", "--eval-samples", "48", "--seed", "7", "--compute-threads", "2",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("done: 1 cycles"), "{stdout}");
    // zero, absurd, and malformed thread counts are usage errors (exit
    // 2), never a thread-spawn panic
    for bad in ["0", "4000000000"] {
        let (_, stderr, ok) = mel(&["info", "--compute-threads", bad]);
        assert!(!ok, "--compute-threads {bad} must fail");
        assert!(stderr.contains("--compute-threads must be within 1..="), "stderr: {stderr}");
        assert!(!stderr.contains("panicked"), "must not panic: {stderr}");
    }
    let (_, stderr, ok) = mel(&["info", "--compute-threads", "many"]);
    assert!(!ok);
    assert!(stderr.contains("--compute-threads expects an integer"), "stderr: {stderr}");
    // the info report surfaces the configured pool size
    let (stdout, _, ok) = mel(&["info", "--compute-threads", "3"]);
    assert!(ok);
    assert!(stdout.contains("compute pool: 3 thread(s)"), "{stdout}");
}

#[test]
fn figure_fig_cluster_renders() {
    let (stdout, stderr, ok) = mel(&["figure", "figCluster", "--seed", "42"]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("updates churn re-lease"), "{stdout}");
    assert!(stdout.contains("updates sync"), "{stdout}");
}

#[test]
fn train_runs_offline_through_native_backend() {
    // the flagship fix of the backend split: real training end to end
    // with no artifacts and no pjrt feature — the old engine error path
    // ("run `make artifacts`") no longer exists on the default route
    let (stdout, stderr, ok) = mel(&[
        "train", "--task", "pedestrian", "--k", "2", "--t", "2", "--d", "96", "--cycles", "1",
        "--hidden", "8", "--eval-samples", "48", "--seed", "7",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("execution backend: native"), "{stdout}");
    assert!(stdout.contains("done: 1 cycles"), "{stdout}");
    assert!(!stderr.contains("make artifacts"), "stderr: {stderr}");
}

#[test]
fn train_forced_pjrt_errors_truthfully_without_feature() {
    if mel::runtime::pjrt_available() {
        return; // on a pjrt box the forced path actually trains
    }
    let (_, stderr, ok) = mel(&[
        "train", "--task", "pedestrian", "--k", "2", "--backend", "pjrt", "--d", "64",
        "--cycles", "1", "--hidden", "8",
    ]);
    assert!(!ok);
    // the error names the missing capability (feature/artifacts)…
    assert!(stderr.contains("pjrt") || stderr.contains("artifacts"), "stderr: {stderr}");
    if !cfg!(feature = "pjrt") {
        // …and points at the native alternative instead of a dead end
        assert!(stderr.contains("native"), "stderr: {stderr}");
    }
    // unknown backend is a usage error
    let (_, stderr, ok) = mel(&["train", "--backend", "frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown backend"), "stderr: {stderr}");
}

#[test]
fn figure_fig_accuracy_renders_offline() {
    let (stdout, stderr, ok) = mel(&[
        "figure", "figAccuracy", "--seed", "42", "--k", "2", "--d", "96", "--cycles", "2",
        "--hidden", "8", "--eval-samples", "48",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("acc_pm pedestrian optimized"), "{stdout}");
    assert!(stdout.contains("acc_pm mnist equal"), "{stdout}");
    assert!(
        stdout.contains("update timelines: identical"),
        "cluster/orchestrator timelines must match: {stdout}"
    );
}

#[test]
fn figure_fig_global_renders_offline() {
    // real multi-shard SGD replay through the parameter server on the
    // hermetic native backend: tiny 1-shard sweep to stay fast
    let (stdout, stderr, ok) = mel(&[
        "figure", "figGlobal", "--seed", "42", "--shards", "1", "--k", "2", "--d", "64",
        "--cycles", "2", "--hidden", "8", "--eval-samples", "48",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("final_acc_pm optimized"), "{stdout}");
    assert!(stdout.contains("updates equal"), "{stdout}");
    assert!(stdout.contains("figGlobal"), "{stdout}");
}

#[test]
fn figure_fig_global_rounds_mode_with_knobs() {
    let (stdout, stderr, ok) = mel(&[
        "figure", "figGlobal", "--seed", "42", "--shards", "1", "--k", "2", "--d", "64",
        "--cycles", "2", "--hidden", "8", "--eval-samples", "48", "--aggregation", "rounds",
        "--round-period", "2.0", "--staleness-discount", "0.25",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("agg=rounds"), "{stdout}");
}

#[test]
fn fig_global_malformed_knobs_are_usage_errors() {
    // malformed numerics: proper usage errors, exit 2, no panic
    let (_, stderr, ok) = mel(&["figure", "figGlobal", "--round-period", "fast"]);
    assert!(!ok);
    assert!(stderr.contains("--round-period expects a number"), "stderr: {stderr}");
    assert!(!stderr.contains("panicked"), "must not panic: {stderr}");

    let (_, stderr, ok) = mel(&["figure", "figGlobal", "--staleness-discount", "0..5"]);
    assert!(!ok);
    assert!(stderr.contains("--staleness-discount expects a number"), "stderr: {stderr}");
    assert!(!stderr.contains("panicked"), "must not panic: {stderr}");

    // out-of-range / inconsistent values are usage errors too
    let (_, stderr, ok) = mel(&["figure", "figGlobal", "--staleness-discount", "1.5"]);
    assert!(!ok);
    assert!(stderr.contains("staleness_discount must be within"), "stderr: {stderr}");

    let (_, stderr, ok) = mel(&["figure", "figGlobal", "--aggregation", "rounds"]);
    assert!(!ok);
    assert!(stderr.contains("round_period_s must be positive"), "stderr: {stderr}");

    let (_, stderr, ok) = mel(&["figure", "figGlobal", "--aggregation", "frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("per_update or rounds"), "stderr: {stderr}");
}

#[test]
fn bench_diff_compares_suite_json() {
    let dir = std::env::temp_dir().join(format!("mel-bench-diff-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let suite = |name: &str, means: &[(&str, f64)]| {
        let results: Vec<String> = means
            .iter()
            .map(|(n, m)| format!("{{\"name\":\"{n}\",\"mean_s\":{m}}}"))
            .collect();
        format!(
            "{{\"suite\":\"{name}\",\"unit\":\"seconds/iter\",\"results\":[{}]}}",
            results.join(",")
        )
    };
    let old_path = dir.join("BENCH_old.json");
    let new_path = dir.join("BENCH_new.json");
    std::fs::write(&old_path, suite("solvers", &[("alloc", 1.0e-3), ("split", 2.0e-3)])).unwrap();
    std::fs::write(&new_path, suite("solvers", &[("alloc", 1.5e-3), ("split", 1.0e-3)])).unwrap();

    let (stdout, stderr, ok) =
        mel(&["bench", "diff", old_path.to_str().unwrap(), new_path.to_str().unwrap()]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("REGRESS"), "{stdout}"); // alloc +50%
    assert!(stdout.contains("improve"), "{stdout}"); // split halved
    assert!(stdout.contains("1 regression(s)"), "{stdout}");

    // --fail-on-regress turns the regression into a nonzero exit
    let (_, _, ok) = mel(&[
        "bench", "diff", old_path.to_str().unwrap(), new_path.to_str().unwrap(),
        "--fail-on-regress",
    ]);
    assert!(!ok);

    // raising the threshold clears it
    let (stdout, _, ok) = mel(&[
        "bench", "diff", old_path.to_str().unwrap(), new_path.to_str().unwrap(),
        "--threshold", "0.6", "--fail-on-regress",
    ]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("0 regression(s)"), "{stdout}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn bench_diff_usage_and_io_errors() {
    let (_, stderr, ok) = mel(&["bench"]);
    assert!(!ok);
    assert!(stderr.contains("usage"), "{stderr}");
    let (_, stderr, ok) = mel(&["bench", "diff", "/no/such/old.json", "/no/such/new.json"]);
    assert!(!ok);
    assert!(stderr.contains("reading"), "{stderr}");
}

#[test]
fn info_reports_backends() {
    let (stdout, _, ok) = mel(&["info"]);
    assert!(ok);
    assert!(stdout.contains("native (always available)"), "{stdout}");
}

#[test]
fn trace_malformed_flags_are_usage_errors() {
    // bad --format: exit 2 before any work happens
    let (_, stderr, code) = mel_code(&["trace", "--format", "bogus"]);
    assert_eq!(code, Some(2), "stderr: {stderr}");
    assert!(stderr.contains("usage error"), "{stderr}");
    assert!(stderr.contains("chrome|prom|csv|all"), "{stderr}");
    // bad --mode
    let (_, stderr, code) = mel_code(&["trace", "--mode", "warp"]);
    assert_eq!(code, Some(2), "stderr: {stderr}");
    assert!(stderr.contains("sync or async"), "{stderr}");
    // an --out path nested under a plain file cannot be created
    let dir = std::env::temp_dir().join(format!("mel-trace-badout-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let file = dir.join("plain-file");
    std::fs::write(&file, "x").unwrap();
    let bad = file.join("sub");
    let (_, stderr, code) = mel_code(&["trace", "--out", bad.to_str().unwrap()]);
    assert_eq!(code, Some(2), "stderr: {stderr}");
    assert!(stderr.contains("cannot create --out"), "{stderr}");
    // unknown scenario task
    let ok_out = dir.join("out");
    let (_, stderr, code) =
        mel_code(&["trace", "--scenario", "frobnicate", "--out", ok_out.to_str().unwrap()]);
    assert_eq!(code, Some(2), "stderr: {stderr}");
    assert!(stderr.contains("unknown scenario"), "{stderr}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn trace_writes_parseable_artifacts() {
    let dir = std::env::temp_dir().join(format!("mel-trace-out-{}", std::process::id()));
    let (stdout, stderr, ok) = mel(&[
        "trace", "--scenario", "pedestrian", "--k", "2", "--t", "2", "--cycles", "2", "--d",
        "96", "--hidden", "8", "--eval-samples", "48", "--seed", "7", "--out",
        dir.to_str().unwrap(), "--format", "all",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("traced "), "{stdout}");

    let chrome = std::fs::read_to_string(dir.join("trace.chrome.json")).expect("chrome file");
    let v = mel::util::json::Json::parse(&chrome).expect("Perfetto-loadable JSON");
    assert!(
        !v.get("traceEvents").unwrap().as_arr().unwrap().is_empty(),
        "empty traceEvents"
    );

    let prom = std::fs::read_to_string(dir.join("metrics.prom")).expect("prom file");
    assert!(prom.contains("# TYPE mel_"), "no metrics in exposition:\n{prom}");

    let csv = std::fs::read_to_string(dir.join("budget.csv")).expect("csv file");
    let mut lines = csv.lines();
    assert_eq!(
        lines.next().unwrap(),
        "shard,learner,dispatch_s,tau,d,send_s,compute_s,upload_s,slack_s,t_total,on_time"
    );
    assert!(lines.count() >= 4, "expected one row per lease:\n{csv}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn trace_live_malformed_flags_are_usage_errors() {
    // --live with a non-boolean value
    let (_, stderr, code) = mel_code(&["trace", "--live", "xyz"]);
    assert_eq!(code, Some(2), "stderr: {stderr}");
    assert!(stderr.contains("--live expects true/false/1/0"), "{stderr}");
    assert!(!stderr.contains("panicked"), "must not panic: {stderr}");
    // malformed --checkpoint-every
    let (_, stderr, code) = mel_code(&["trace", "--live", "--checkpoint-every", "notanint"]);
    assert_eq!(code, Some(2), "stderr: {stderr}");
    assert!(stderr.contains("--checkpoint-every expects an integer"), "{stderr}");
    // malformed --plane-capacity
    let (_, stderr, code) = mel_code(&["trace", "--live", "--plane-capacity", "lots"]);
    assert_eq!(code, Some(2), "stderr: {stderr}");
    assert!(stderr.contains("--plane-capacity expects an integer"), "{stderr}");
    // a zero plane capacity fails spec validation before any work
    let (_, stderr, code) = mel_code(&["trace", "--live", "--plane-capacity", "0"]);
    assert_eq!(code, Some(2), "stderr: {stderr}");
    assert!(stderr.contains("plane_capacity"), "{stderr}");
    // an empty --journal value
    let (_, stderr, code) = mel_code(&["trace", "--live", "--journal="]);
    assert_eq!(code, Some(2), "stderr: {stderr}");
    assert!(stderr.contains("--journal expects a directory path"), "{stderr}");
    // durability knobs without --live are inconsistent usage
    let (_, stderr, code) = mel_code(&["trace", "--journal", "somewhere"]);
    assert_eq!(code, Some(2), "stderr: {stderr}");
    assert!(
        stderr.contains("--journal/--checkpoint-every/--plane-capacity require --live"),
        "{stderr}"
    );
    // `mel resume` without a journal directory
    let (_, stderr, code) = mel_code(&["resume"]);
    assert_eq!(code, Some(2), "stderr: {stderr}");
    assert!(stderr.contains("resume needs --journal"), "{stderr}");
    // `mel resume` pointing at a directory with no run manifest
    let dir = std::env::temp_dir().join(format!("mel-resume-empty-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let (_, stderr, code) = mel_code(&["resume", "--journal", dir.to_str().unwrap()]);
    assert_eq!(code, Some(2), "stderr: {stderr}");
    assert!(stderr.contains("run.json"), "{stderr}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn trace_live_writes_journal_artifacts_and_resume_replays_them() {
    let base = std::env::temp_dir().join(format!("mel-cli-live-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    let out = base.join("out");
    let journal = base.join("journal");
    let (stdout, stderr, ok) = mel(&[
        "trace", "--scenario", "pedestrian", "--k", "2", "--t", "2", "--cycles", "2", "--d",
        "96", "--hidden", "8", "--eval-samples", "48", "--seed", "7", "--out",
        out.to_str().unwrap(), "--live", "--journal", journal.to_str().unwrap(),
        "--checkpoint-every", "1",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("traced "), "{stdout}");

    // durability artifacts: an append-only journal, the last
    // checkpoint, and the run manifest `mel resume` rebuilds from
    let journal_text =
        std::fs::read_to_string(journal.join("journal.jsonl")).expect("journal file");
    assert!(!journal_text.trim().is_empty(), "empty journal");
    for line in journal_text.lines() {
        let rec = mel::util::json::Json::parse(line).expect("journal line parses");
        rec.get("shard").unwrap().as_u64().expect("shard field");
        rec.get("learner").unwrap().as_u64().expect("learner field");
    }
    let ck = std::fs::read_to_string(journal.join("checkpoint.json")).expect("checkpoint");
    let ck = mel::util::json::Json::parse(&ck).expect("checkpoint parses");
    assert_eq!(ck.get("format").unwrap().as_u64().unwrap(), 1);
    let manifest = std::fs::read_to_string(journal.join("run.json")).expect("run manifest");
    let manifest = mel::util::json::Json::parse(&manifest).expect("run.json parses");
    assert_eq!(manifest.get("format").unwrap().as_u64().unwrap(), 1);
    assert!(manifest.get("spec").is_ok(), "manifest must embed the cluster spec");

    // the journaled run resumes (here: a no-op tail after a completed
    // stream) and reports the same update/apply accounting
    let (stdout, stderr, code) = mel_code(&["resume", "--journal", journal.to_str().unwrap()]);
    assert_eq!(code, Some(0), "stderr: {stderr}");
    assert!(stdout.contains("resumed from"), "{stdout}");
    let _ = std::fs::remove_dir_all(&base);
}

#[test]
fn lint_clean_tree_and_json_output() {
    // the tree lints itself clean (exit 0); JSON output parses and has
    // the baseline-file shape
    let (stdout, stderr, code) = mel_code(&["lint", "--format", "json"]);
    assert_eq!(code, Some(0), "stderr: {stderr}\nstdout: {stdout}");
    let v = mel::util::json::Json::parse(&stdout).expect("lint JSON parses");
    assert_eq!(v.get("format").unwrap().as_u64().unwrap(), 1);
    assert!(v.get("files_scanned").unwrap().as_u64().unwrap() > 50, "{stdout}");
    assert!(v.get("findings").unwrap().as_arr().unwrap().is_empty(), "{stdout}");
    // human mode agrees
    let (stdout, _, code) = mel_code(&["lint"]);
    assert_eq!(code, Some(0));
    assert!(stdout.contains("mel lint: clean"), "{stdout}");
}

#[test]
fn lint_usage_errors_exit_2() {
    let (_, stderr, code) = mel_code(&["lint", "--format", "bogus"]);
    assert_eq!(code, Some(2), "stderr: {stderr}");
    assert!(stderr.contains("--format must be human|json"), "{stderr}");
    // unreadable baseline path
    let (_, stderr, code) = mel_code(&["lint", "--baseline", "/no/such/baseline.json"]);
    assert_eq!(code, Some(2), "stderr: {stderr}");
    assert!(stderr.contains("--baseline"), "{stderr}");
    // malformed baseline content
    let dir = std::env::temp_dir().join(format!("mel-lint-base-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let bad = dir.join("bad.json");
    std::fs::write(&bad, "not json").unwrap();
    let (_, stderr, code) = mel_code(&["lint", "--baseline", bad.to_str().unwrap()]);
    assert_eq!(code, Some(2), "stderr: {stderr}");
    assert!(stderr.contains("bad --baseline"), "{stderr}");
    // nonexistent explicit path
    let (_, stderr, code) = mel_code(&["lint", "rust/src/no_such_file.rs"]);
    assert_eq!(code, Some(2), "stderr: {stderr}");
    assert!(stderr.contains("no such file or directory"), "{stderr}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn lint_findings_exit_1_and_baseline_silences_them() {
    let dir = std::env::temp_dir().join(format!("mel-lint-fixture-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let bad = dir.join("bad_fixture.rs");
    std::fs::write(
        &bad,
        "pub fn f(v: &mut Vec<f64>) {\n    v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n",
    )
    .unwrap();
    let (stdout, stderr, code) = mel_code(&["lint", bad.to_str().unwrap()]);
    assert_eq!(code, Some(1), "stderr: {stderr}\nstdout: {stdout}");
    assert!(stdout.contains("D1"), "{stdout}");
    assert!(stdout.contains("bad_fixture.rs:2"), "{stdout}");
    // a failing run's JSON output doubles as a baseline that silences
    // exactly those findings
    let (json, _, code) = mel_code(&["lint", "--format", "json", bad.to_str().unwrap()]);
    assert_eq!(code, Some(1));
    let base = dir.join("baseline.json");
    std::fs::write(&base, &json).unwrap();
    let (stdout, _, code) =
        mel_code(&["lint", "--baseline", base.to_str().unwrap(), bad.to_str().unwrap()]);
    assert_eq!(code, Some(0), "{stdout}");
    assert!(stdout.contains("baselined"), "{stdout}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sweep_renders_and_writes_csv() {
    let (stdout, stderr, ok) = mel(&[
        "sweep", "--task", "mnist", "--ks", "5,10", "--ts", "60,120", "--policy", "sai",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("gain_vs_eta"));
    // 2 x 2 grid rows plus borders/header
    assert!(stdout.matches('\n').count() >= 8);
}
