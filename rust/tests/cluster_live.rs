//! Live-streaming parameter-server plane (ISSUE 9):
//!
//! 1. **Live ≡ replay** — `Cluster::run_live` (shards streaming
//!    `UpdateRecord`s over the bounded plane, server applying cohorts
//!    as the safe simulated-time cut advances) is bit-for-bit equal to
//!    the `Cluster::run_global` replay oracle on a churning 2-shard
//!    async cluster, in both rounds and per-update aggregation.
//! 2. **Crash resume** — a run killed mid-stream (via the
//!    `halt_after_applies` hook) leaves a journal + checkpoint from
//!    which a resumed `run_live` reproduces the uninterrupted run's
//!    final parameters and loss/accuracy series exactly.
//!
//! Both properties are CI-gated at `MEL_THREADS=1` and `4` (see ci.sh).

use mel::alloc::Policy;
use mel::cluster::{
    Cluster, ClusterConfig, ClusterReport, GlobalReport, LiveOptions, ParamServerConfig,
};
use mel::coordinator::ParamSet;
use mel::orchestrator::Mode;
use mel::scenario::{
    AggregationMode, ChurnTrace, CloudletConfig, ClusterSpec, GlobalAggSpec, ShardSpec,
};

const T: f64 = 2.0;
const CYCLES: usize = 3;
const LR: f32 = 0.05;
const EVAL: usize = 48;
const SEED: u64 = 42;

/// Debug-build-friendly cloudlet: paper timing constants drive the
/// allocation while the executed graph uses a shrunken hidden layer.
fn tiny_cloudlet(k: usize, d: usize) -> CloudletConfig {
    let mut c = CloudletConfig::pedestrian(k);
    c.model = c.model.with_hidden(&[8]);
    c.dataset.total_samples = d;
    c
}

/// A 2-shard cluster of tiny cloudlets with synthetic churn and the
/// requested global-aggregation mode.
fn churny_spec(aggregation: AggregationMode, staleness_discount: f64) -> ClusterSpec {
    let ccfg = tiny_cloudlet(3, 96);
    ClusterSpec {
        shards: (0..2)
            .map(|i| ShardSpec {
                cloudlet: ccfg.clone(),
                seed_offset: i as u64,
                churn: ChurnTrace::default(),
                population: None,
            })
            .collect(),
        global: GlobalAggSpec {
            aggregation,
            round_period_s: T,
            staleness_discount,
            ..GlobalAggSpec::default()
        },
    }
    .with_synthetic_churn(CYCLES as f64 * T, 1, SEED)
}

fn cluster_for(spec: &ClusterSpec) -> Cluster {
    Cluster::new(
        spec.clone(),
        ClusterConfig {
            policy: Policy::Analytical,
            mode: Mode::Async,
            t_total: T,
            cycles: CYCLES,
            seed: SEED,
            ..ClusterConfig::default()
        },
    )
}

fn ps_cfg_for(spec: &ClusterSpec) -> ParamServerConfig {
    ParamServerConfig {
        lr: LR,
        eval_samples: EVAL,
        ..ParamServerConfig::from_spec(&spec.global, SEED)
    }
}

fn assert_params_bit_equal(a: &ParamSet, b: &ParamSet, what: &str) {
    assert_eq!(a.tensors.len(), b.tensors.len(), "{what}: tensor count");
    for (i, (ta, tb)) in a.tensors.iter().zip(&b.tensors).enumerate() {
        assert_eq!(ta.dims, tb.dims, "{what}: tensor {i} dims");
        for (j, (x, y)) in ta.as_f32().iter().zip(tb.as_f32()).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{what}: tensor {i} coord {j}: {x} vs {y}"
            );
        }
    }
}

fn assert_series_bit_equal(a: &[(f64, f64)], b: &[(f64, f64)], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, ((ta, va), (tb, vb))) in a.iter().zip(b).enumerate() {
        assert_eq!(ta.to_bits(), tb.to_bits(), "{what}: point {i} time");
        assert_eq!(va.to_bits(), vb.to_bits(), "{what}: point {i} value");
    }
}

fn assert_timelines_bit_equal(a: &ClusterReport, b: &ClusterReport, what: &str) {
    assert_eq!(a.updates.len(), b.updates.len(), "{what}: update count");
    for (i, ((sa, ua), (sb, ub))) in a.updates.iter().zip(&b.updates).enumerate() {
        assert_eq!(sa, sb, "{what}: update {i} shard");
        assert_eq!(ua.learner, ub.learner, "{what}: update {i} learner");
        assert_eq!(
            ua.dispatched_at.to_bits(),
            ub.dispatched_at.to_bits(),
            "{what}: update {i} dispatch"
        );
        assert_eq!(
            ua.uploaded_at.to_bits(),
            ub.uploaded_at.to_bits(),
            "{what}: update {i} upload"
        );
        assert_eq!(ua.tau, ub.tau, "{what}: update {i} tau");
        assert_eq!(ua.batch, ub.batch, "{what}: update {i} batch");
        assert_eq!(ua.staleness, ub.staleness, "{what}: update {i} staleness");
        assert_eq!(ua.missed_deadline, ub.missed_deadline, "{what}: update {i} miss");
    }
}

fn assert_globals_bit_equal(a: &GlobalReport, b: &GlobalReport, what: &str) {
    assert_eq!(a.applies, b.applies, "{what}: applies");
    assert_eq!(a.updates_replayed, b.updates_replayed, "{what}: updates replayed");
    assert_eq!(a.final_loss.to_bits(), b.final_loss.to_bits(), "{what}: final loss");
    assert_eq!(
        a.final_accuracy.to_bits(),
        b.final_accuracy.to_bits(),
        "{what}: final accuracy"
    );
    assert_params_bit_equal(&a.params, &b.params, what);
    assert_series_bit_equal(&a.loss_series, &b.loss_series, &format!("{what}: loss series"));
    assert_series_bit_equal(&a.acc_series, &b.acc_series, &format!("{what}: acc series"));
    assert_eq!(a.rounds.len(), b.rounds.len(), "{what}: round count");
    for (i, (ra, rb)) in a.rounds.iter().zip(&b.rounds).enumerate() {
        assert_eq!(ra.index, rb.index, "{what}: round {i} index");
        assert_eq!(ra.weight.to_bits(), rb.weight.to_bits(), "{what}: round {i} weight");
    }
}

fn live_equals_replay(aggregation: AggregationMode, staleness_discount: f64, what: &str) {
    let spec = churny_spec(aggregation, staleness_discount);

    // the deterministic oracle: full timing run, then an offline replay
    let oracle = cluster_for(&spec);
    let (ref_report, ref_global) =
        oracle.run_global(ps_cfg_for(&spec)).expect("replay oracle run");
    assert!(!ref_report.updates.is_empty(), "{what}: oracle produced no updates");
    assert!(
        ref_report.shards.iter().any(|s| s.joins + s.departs > 0),
        "{what}: no churn in the oracle run"
    );

    // the live plane, with a deliberately tiny channel so backpressure
    // (blocking senders) is actually exercised
    let live = cluster_for(&spec);
    let opts = LiveOptions { plane_capacity: 2, ..LiveOptions::default() };
    let (live_report, live_global) =
        live.run_live(ps_cfg_for(&spec), &opts).expect("live run");

    assert_timelines_bit_equal(&live_report, &ref_report, what);
    assert_globals_bit_equal(&live_global, &ref_global, what);
}

#[test]
fn live_rounds_aggregation_matches_replay_bit_for_bit_under_churn() {
    live_equals_replay(AggregationMode::Rounds, 0.0, "rounds live≡replay");
}

#[test]
fn live_per_update_aggregation_matches_replay_bit_for_bit_under_churn() {
    live_equals_replay(AggregationMode::PerUpdate, 0.2, "per-update live≡replay");
}

/// Fresh tempdir for one test's journal artifacts.
fn journal_tempdir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("mel-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create journal tempdir");
    dir
}

#[test]
fn killed_live_run_resumes_bit_for_bit_from_journal_and_checkpoint() {
    let spec = churny_spec(AggregationMode::PerUpdate, 0.0);

    // uninterrupted oracle
    let oracle = cluster_for(&spec);
    let (_, ref_global) = oracle.run_global(ps_cfg_for(&spec)).expect("replay oracle run");
    assert!(ref_global.applies > 2, "need enough applies to kill mid-run");

    let dir = journal_tempdir("resume");

    // crash mid-stream: checkpoint every apply, abandon after two
    let halted = cluster_for(&spec);
    let halt_opts = LiveOptions {
        checkpoint_every: 1,
        journal_dir: Some(dir.clone()),
        plane_capacity: 2,
        halt_after_applies: Some(2),
        ..LiveOptions::default()
    };
    let err = halted
        .run_live(ps_cfg_for(&spec), &halt_opts)
        .expect_err("halt hook must abort the run");
    assert!(
        format!("{err}").contains("halted early"),
        "unexpected halt error: {err}"
    );
    assert!(dir.join("journal.jsonl").exists(), "journal must survive the crash");
    assert!(dir.join("checkpoint.json").exists(), "checkpoint must survive the crash");

    // resume: replays the journaled prefix, restores the checkpoint,
    // and streams the rest live — bit-identical to never crashing
    let resumed = cluster_for(&spec);
    let resume_opts = LiveOptions {
        checkpoint_every: 1,
        journal_dir: Some(dir.clone()),
        resume: true,
        plane_capacity: 2,
        ..LiveOptions::default()
    };
    let (_, live_global) =
        resumed.run_live(ps_cfg_for(&spec), &resume_opts).expect("resumed run");

    assert_globals_bit_equal(&live_global, &ref_global, "crash-resume");

    let _ = std::fs::remove_dir_all(&dir);
}
