//! Property tests for the churn-aware cluster layer:
//!
//! 1. **Sample conservation** — after every Join/Depart re-split the
//!    planned batches sum to the full dataset (`Σ_k d_k = d`), with
//!    departed learners holding exactly 0.
//! 2. **Straggler re-lease geometry** — the consecutive-miss re-lease
//!    batch sequence is strictly monotonically shrinking and
//!    terminates (the learner is parked at the batch floor), for every
//!    shrink factor in (0, 1).

use mel::alloc::{Policy, Problem};
use mel::cluster::{shard_seed, Cluster, ClusterConfig, ChurnAwarePlanner};
use mel::learner::Coeffs;
use mel::orchestrator::{CyclePlanner, Mode, Redispatch};
use mel::scenario::ClusterSpec;
use mel::util::rng::{Pcg64, Rng};

/// Random heterogeneous problem in the calibrated two-class envelope —
/// generous `T` so any non-empty subset of learners stays feasible
/// (conservation is only claimed for successful re-splits).
fn random_problem(rng: &mut Pcg64, k: usize, d: usize) -> Problem {
    let coeffs = (0..k)
        .map(|i| {
            let fast = i % 2 == 0;
            let base = if fast { 651e-6 } else { 4464e-6 };
            Coeffs {
                c2: base * rng.uniform(0.5, 2.0),
                c1: 36e-6 * rng.uniform(0.5, 2.0),
                c0: 0.086 * rng.uniform(0.5, 2.0),
            }
        })
        .collect();
    Problem { coeffs, total_samples: d, t_total: 200.0 }
}

#[test]
fn resplit_conserves_samples_across_random_churn_sequences() {
    let mut rng = Pcg64::seeded(2024);
    for trial in 0..30 {
        let k = 3 + (rng.below(9) as usize);
        let d = 1000 + (rng.below(4000) as usize);
        let p = random_problem(&mut rng, k, d);
        let mut planner = ChurnAwarePlanner::new(Policy::Analytical, vec![true; k]);
        let plan = planner.plan_round(&p, 0.0).unwrap();
        assert_eq!(
            plan.alloc.batches.iter().sum::<usize>(),
            d,
            "trial {trial}: initial split must place every sample"
        );

        let mut member = vec![true; k];
        let mut t = 1.0;
        for _step in 0..20 {
            // random membership toggle, always keeping ≥ 2 active
            let learner = rng.below(k as u64) as usize;
            let joined = !member[learner];
            if !joined && member.iter().filter(|&&m| m).count() <= 2 {
                continue;
            }
            member[learner] = joined;
            planner.on_membership(learner, joined, &p, t);
            t += 1.0;

            assert_eq!(planner.resplit_failures(), 0, "trial {trial}: generous T");
            let planned = planner.planned_batches();
            assert_eq!(
                planned.iter().sum::<usize>(),
                d,
                "trial {trial}: conservation after {}",
                if joined { "join" } else { "depart" }
            );
            for (idx, &b) in planned.iter().enumerate() {
                if !member[idx] {
                    assert_eq!(b, 0, "trial {trial}: departed learner {idx} holds samples");
                }
            }
        }
    }
}

#[test]
fn straggler_release_sequence_shrinks_monotonically_and_terminates() {
    let mut rng = Pcg64::seeded(77);
    for trial in 0..30 {
        let k = 2 + (rng.below(8) as usize);
        let d = 500 + (rng.below(5000) as usize);
        let p = random_problem(&mut rng, k, d);
        let shrink = rng.uniform(0.2, 0.9);
        let mut planner =
            ChurnAwarePlanner::new(Policy::Analytical, vec![true; k]).with_shrink(shrink);
        planner.plan_round(&p, 0.0).unwrap();

        // straggle the most loaded learner (guaranteed a real batch)
        let learner = planner
            .lease_batches()
            .iter()
            .enumerate()
            .max_by_key(|(_, &b)| b)
            .map(|(i, _)| i)
            .unwrap();
        let start = planner.lease_batches()[learner];
        assert!(start > 1, "trial {trial}: max share must exceed the floor");
        let mut seq = vec![start];
        for step in 0.. {
            match planner.on_deadline_miss(learner, &p, step as f64) {
                Redispatch::Immediate(lease) => {
                    assert_eq!(lease.learner, learner);
                    assert!(lease.tau >= 1, "a re-lease must still do work");
                    seq.push(lease.batch);
                }
                Redispatch::AwaitBarrier => break, // parked: terminated
            }
            assert!(
                step < 128,
                "trial {trial}: shrink {shrink:.2} from {start} must terminate: {seq:?}"
            );
        }
        assert!(
            seq.windows(2).all(|w| w[1] < w[0]),
            "trial {trial}: not strictly shrinking: {seq:?}"
        );
        // parked exactly at the batch floor
        assert_eq!(*seq.last().unwrap(), 1, "trial {trial}: {seq:?}");
    }
}

/// Shard RNG streams are a pure function of `(cluster_seed, shard_id)`
/// (plus the spec's `seed_offset` knob): two identical `Cluster::run`s
/// — each spawning its own thread per shard, under churn, fading,
/// deadline pressure, and straggler re-leasing — must produce
/// *identical* merged timelines, bit for bit. Host thread scheduling
/// must never leak into the simulated streams.
#[test]
fn identical_cluster_runs_produce_identical_merged_timelines() {
    let run = || {
        let spec = ClusterSpec::uniform("pedestrian", 3, 5)
            .unwrap()
            .with_synthetic_churn(240.0, 2, 9);
        let cfg = ClusterConfig {
            policy: Policy::Analytical,
            mode: Mode::Async,
            t_total: 30.0,
            lease_s: 25.0,
            cycles: 8,
            straggler_releasing: true,
            rayleigh: true,
            seed: 7,
            ..ClusterConfig::default()
        };
        Cluster::new(spec, cfg).run().unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.updates.len(), b.updates.len());
    assert_eq!(a.updates_applied, b.updates_applied);
    assert_eq!(a.deadline_misses, b.deadline_misses);
    assert_eq!(a.releases, b.releases);
    for ((sa, ua), (sb, ub)) in a.updates.iter().zip(&b.updates) {
        assert_eq!(sa, sb, "shard tags diverged");
        assert_eq!(ua.learner, ub.learner);
        assert_eq!(ua.dispatched_at.to_bits(), ub.dispatched_at.to_bits());
        assert_eq!(ua.uploaded_at.to_bits(), ub.uploaded_at.to_bits());
        assert_eq!(ua.tau, ub.tau);
        assert_eq!(ua.batch, ub.batch);
        assert_eq!(ua.staleness, ub.staleness);
        assert_eq!(ua.missed_deadline, ub.missed_deadline);
    }
    // the derivation itself: shard 0 keeps the cluster seed (the
    // single-shard equivalence contract), later shards fold their id in
    assert_eq!(shard_seed(7, 0, 0), 7);
    assert_ne!(shard_seed(7, 0, 1), shard_seed(7, 1, 0));
    assert_ne!(shard_seed(7, 0, 1), shard_seed(7, 0, 2));
}

#[test]
fn punctual_uploads_recover_toward_planned_share() {
    // recovery growth is capped by the planned share and monotone
    let mut rng = Pcg64::seeded(5);
    let p = random_problem(&mut rng, 6, 3000);
    let mut planner = ChurnAwarePlanner::new(Policy::Analytical, vec![true; 6]);
    planner.plan_round(&p, 0.0).unwrap();
    let learner = planner
        .planned_batches()
        .iter()
        .enumerate()
        .max_by_key(|(_, &b)| b)
        .map(|(i, _)| i)
        .unwrap();
    let planned = planner.planned_batches()[learner];
    for _ in 0..4 {
        let _ = planner.on_deadline_miss(learner, &p, 1.0);
    }
    let mut last = planner.lease_batches()[learner];
    assert!(last < planned);
    for step in 0..12 {
        match planner.on_upload(learner, &p, 2.0 + step as f64) {
            Redispatch::Immediate(lease) => {
                assert!(lease.batch >= last && lease.batch <= planned);
                last = lease.batch;
            }
            Redispatch::AwaitBarrier => panic!("active learner must be re-dispatched"),
        }
    }
    assert_eq!(last, planned, "growth must recover the full planned share");
}
