//! Sync-mode equivalence regression: the event-driven orchestrator in
//! barrier mode must reproduce the seed coordinator's per-cycle numbers
//! — τ, batches, makespan — **exactly** (bit-for-bit f64), for fixed
//! seeds. The seed path is replicated here as the closed-form reference
//! it was: a `Policy` solve plus the eq. (13) `CycleSim` timeline. This
//! is the contract that lets every async extension share the sync
//! timing model without re-validating the paper's figures.

use mel::alloc::Policy;
use mel::cluster::{Cluster, ClusterConfig};
use mel::orchestrator::{Mode, Orchestrator, OrchestratorConfig};
use mel::scenario::{CloudletConfig, ClusterSpec, Scenario};
use mel::sim::CycleSim;
use mel::util::rng::Pcg64;

fn sync_cfg(policy: Policy, t: f64, cycles: usize, seed: u64) -> OrchestratorConfig {
    OrchestratorConfig {
        mode: Mode::Sync,
        policy,
        t_total: t,
        cycles,
        seed,
        ..OrchestratorConfig::default()
    }
}

#[test]
fn static_channels_match_seed_coordinator_exactly() {
    for seed in [1u64, 2, 3] {
        for policy in [Policy::Analytical, Policy::Eta, Policy::UbSai] {
            let scenario = Scenario::random_cloudlet(&CloudletConfig::pedestrian(8), seed);
            // --- seed reference: one solve (static channels cache), then
            // the closed-form eq. (13) timeline each cycle
            let problem = scenario.problem(30.0);
            let ref_alloc = policy.allocator().allocate(&problem).unwrap();
            let ref_report = CycleSim::from_problem(&problem).run_cycle(&ref_alloc, false);

            // --- event-driven orchestrator, barrier mode
            let mut orch = Orchestrator::new(scenario, sync_cfg(policy, 30.0, 4, seed));
            let run = orch.run().unwrap();
            assert_eq!(run.rounds.len(), 4);
            for round in &run.rounds {
                assert_eq!(round.alloc.tau, ref_alloc.tau, "seed {seed} {policy:?}");
                assert_eq!(round.alloc.batches, ref_alloc.batches, "seed {seed} {policy:?}");
                // bit-for-bit: same float expressions on both paths
                assert_eq!(round.makespan, ref_report.makespan, "seed {seed} {policy:?}");
                assert_eq!(round.completion, ref_report.completion, "seed {seed} {policy:?}");
                assert!(round.deadline_misses.is_empty());
            }
        }
    }
}

#[test]
fn fading_channels_match_closed_form_replica() {
    // Under per-cycle Rayleigh + shadowing with re-solve, the orchestrator
    // must still agree with a hand-rolled seed-style loop that uses the
    // core's documented fading convention (Pcg64 stream 0xFAD, one
    // redraw per cycle before the solve).
    for seed in [1u64, 2, 3] {
        let cloudlet = {
            let mut c = CloudletConfig::pedestrian(6);
            c.channel.rayleigh = true;
            c.channel.shadow_sigma_db = 3.0;
            c
        };
        let cycles = 5;

        // --- replica loop (closed form)
        let mut replica = Scenario::random_cloudlet(&cloudlet, seed);
        let mut fade_rng = Pcg64::new(seed, 0xFAD);
        let mut spec = mel::channel::ChannelSpec::default();
        spec.rayleigh = true;
        spec.shadow_sigma_db = 3.0;
        let mut expected = Vec::new();
        for _ in 0..cycles {
            replica.redraw_fading(&spec, &mut fade_rng);
            let p = replica.problem(30.0);
            let a = Policy::UbSai.allocator().allocate(&p).unwrap();
            let rep = CycleSim::from_problem(&p).run_cycle(&a, false);
            expected.push((a.tau, a.batches.clone(), rep.makespan));
        }

        // --- event-driven orchestrator
        let scenario = Scenario::random_cloudlet(&cloudlet, seed);
        let mut cfg = sync_cfg(Policy::UbSai, 30.0, cycles, seed);
        cfg.rayleigh = true;
        cfg.shadow_sigma_db = 3.0;
        cfg.reallocate_each_cycle = true;
        let mut orch = Orchestrator::new(scenario, cfg);
        let run = orch.run().unwrap();
        for (round, (tau, batches, makespan)) in run.rounds.iter().zip(&expected) {
            assert_eq!(round.alloc.tau, *tau, "seed {seed} cycle {}", round.cycle);
            assert_eq!(&round.alloc.batches, batches, "seed {seed} cycle {}", round.cycle);
            assert_eq!(round.makespan, *makespan, "seed {seed} cycle {}", round.cycle);
        }
    }
}

#[test]
fn single_shard_zero_churn_cluster_matches_sync_planner_bit_for_bit() {
    // The cluster layer must be a *transparent* wrapper at shard count
    // one with no churn: same SyncPlanner timeline, identical floats.
    for seed in [1u64, 5, 9] {
        // --- reference: the event-driven orchestrator in barrier mode
        let scenario = Scenario::random_cloudlet(&CloudletConfig::pedestrian(8), seed);
        let mut orch = Orchestrator::new(scenario, sync_cfg(Policy::Analytical, 30.0, 4, seed));
        let reference = orch.run().unwrap();

        // --- one sync shard, no churn
        let spec = ClusterSpec::uniform("pedestrian", 1, 8).unwrap();
        let cfg = ClusterConfig {
            policy: Policy::Analytical,
            mode: Mode::Sync,
            t_total: 30.0,
            cycles: 4,
            seed,
            ..ClusterConfig::default()
        };
        let cluster = Cluster::new(spec, cfg).run().unwrap();
        assert_eq!(cluster.shards.len(), 1);
        let shard = &cluster.shards[0].report;

        assert_eq!(shard.rounds.len(), reference.rounds.len());
        for (a, b) in shard.rounds.iter().zip(&reference.rounds) {
            assert_eq!(a.alloc.tau, b.alloc.tau, "seed {seed}");
            assert_eq!(a.alloc.batches, b.alloc.batches, "seed {seed}");
            // bit-for-bit: same float expressions on both paths
            assert_eq!(a.makespan, b.makespan, "seed {seed}");
            assert_eq!(a.completion, b.completion, "seed {seed}");
            assert_eq!(a.deadline_misses, b.deadline_misses, "seed {seed}");
        }
        assert_eq!(cluster.updates_applied, reference.updates_applied);
        assert_eq!(cluster.updates.len(), reference.updates.len());
        // the cluster merges updates by upload time (stable); apply the
        // same ordering to the reference stream before comparing
        let mut ref_sorted: Vec<_> = reference.updates.clone();
        ref_sorted.sort_by(|a, b| a.uploaded_at.total_cmp(&b.uploaded_at));
        for ((_, a), b) in cluster.updates.iter().zip(&ref_sorted) {
            assert_eq!(a.learner, b.learner);
            assert_eq!(a.uploaded_at, b.uploaded_at, "seed {seed}");
            assert_eq!(a.batch, b.batch);
            assert_eq!(a.tau, b.tau);
        }
        assert_eq!(cluster.horizon, 120.0);
    }
}

#[test]
fn four_shard_churn_releasing_beats_drop_baseline() {
    // Acceptance: a 4-shard churn scenario under deadline pressure
    // completes with strictly more applied updates when stragglers are
    // re-leased (shrunken batches, late updates applied) than under the
    // drop-on-miss baseline.
    let spec = || {
        ClusterSpec::uniform("pedestrian", 4, 6)
            .unwrap()
            .with_synthetic_churn(240.0, 2, 42)
    };
    let cfg = |releasing: bool| ClusterConfig {
        policy: Policy::Analytical,
        mode: Mode::Async,
        t_total: 30.0,
        lease_s: 24.0, // deadline pressure manufactures stragglers
        cycles: 8,
        straggler_releasing: releasing,
        seed: 42,
        ..ClusterConfig::default()
    };
    let releasing = Cluster::new(spec(), cfg(true)).run().unwrap();
    let dropping = Cluster::new(spec(), cfg(false)).run().unwrap();
    assert_eq!(releasing.shards.len(), 4);
    assert!(dropping.deadline_misses > 0);
    assert!(releasing.releases > 0);
    assert!(
        releasing.updates_applied > dropping.updates_applied,
        "re-leasing {} must strictly beat drop-on-miss {}",
        releasing.updates_applied,
        dropping.updates_applied
    );
    // churn actually happened on every shard
    for sr in &releasing.shards {
        assert!(sr.joins + sr.departs > 0, "shard {} saw no churn", sr.shard);
        assert!(sr.resplits >= 2);
    }
}

#[test]
fn async_mode_runs_end_to_end_with_staggered_timeline() {
    // Acceptance check: async mode produces per-learner τ_k and visibly
    // staggered re-dispatch in the event timeline.
    let mut cloudlet = CloudletConfig::pedestrian(6);
    cloudlet.channel.rayleigh = true;
    let scenario = Scenario::random_cloudlet(&cloudlet, 1);
    let mut cfg = sync_cfg(Policy::Eta, 30.0, 5, 1);
    cfg.mode = Mode::Async;
    cfg.rayleigh = true;
    cfg.trace = true;
    cfg.drop_stragglers = true;
    let mut orch = Orchestrator::new(scenario, cfg);
    let run = orch.run().unwrap();

    assert!(run.updates_applied > 0);
    // per-learner τ_k heterogeneity
    let taus: std::collections::BTreeSet<u64> =
        run.updates.iter().map(|u| u.tau).collect();
    assert!(taus.len() > 1, "expected heterogeneous τ_k, got {taus:?}");
    // staggered re-dispatch: dispatches at strictly increasing,
    // non-barrier times for some learner
    let dispatches: Vec<f64> = run
        .timeline
        .iter()
        .filter(|(_, e)| matches!(e, mel::orchestrator::LearnerEvent::Dispatched { .. }))
        .map(|(t, _)| *t)
        .collect();
    assert!(
        dispatches.iter().any(|&t| t > 0.0 && (t % 30.0) > 1e-9 && (t % 30.0) < 30.0 - 1e-9),
        "re-dispatch should land off the barrier grid: {dispatches:?}"
    );
    // the timeline is time-ordered
    assert!(run.timeline.windows(2).all(|w| w[0].0 <= w[1].0));
}
