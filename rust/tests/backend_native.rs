//! Gradient correctness of the hermetic native backend.
//!
//! * Property test: analytic `grad_step` gradients match central finite
//!   differences of the loss, per layer, over random small MLP shapes,
//!   random parameters, and random masked batches.
//! * Property test: the thread-pooled execution path equals the serial
//!   path **bit for bit** across random shapes, batches, and thread
//!   counts (ISSUE 5 — pooled matmul determinism).
//! * Golden-value tests: the closed-form zero-parameter loss `n·ln C`,
//!   bit-exact determinism of a seeded 10-step SGD run (now asserted
//!   invariant across pool sizes too; `ci.sh` re-runs these under
//!   `MEL_THREADS=1` and `MEL_THREADS=4`), and strict loss descent over
//!   those 10 updates.
//! * ISSUE 6 — fused + quantized execution: the `fused_step` gradients
//!   (recovered from the in-call SGD update) match finite differences
//!   of the fused loss with the relu-kink detection kept; the quantized
//!   path's analytic gradients match finite differences at a 24-bit
//!   grid (fine enough that the snapped loss stays FD-smooth); the
//!   8/16-bit paths are run-to-run and thread-count deterministic and
//!   within a generous grid-derived divergence bound of f32. `ci.sh`
//!   re-runs the `fused`/`quantized` filters under `MEL_THREADS=1`
//!   and `=4`.

use mel::backend::{Backend, Call, Function, NativeBackend};
use mel::coordinator::ParamSet;
use mel::dataset::{DatasetSpec, SyntheticDataset};
use mel::runtime::Tensor;
use mel::testkit::{forall, one_of, tuple2, u64_range, usize_range};
use mel::util::rng::{Pcg64, Rng};

fn grad_call(layers: &[usize]) -> Call {
    Call::new(Function::GradStep, "toy", layers)
}

/// Random params + batch for the given widths; `masked` rows get 0.
fn random_inputs(layers: &[usize], batch: usize, masked: usize, seed: u64) -> Vec<Tensor> {
    let mut rng = Pcg64::seeded(seed);
    let mut inputs = Vec::new();
    for w in layers.windows(2) {
        let weights: Vec<f32> =
            (0..w[0] * w[1]).map(|_| rng.uniform(-0.8, 0.8) as f32).collect();
        let biases: Vec<f32> = (0..w[1]).map(|_| rng.uniform(-0.3, 0.3) as f32).collect();
        inputs.push(Tensor::f32(vec![w[0], w[1]], weights));
        inputs.push(Tensor::f32(vec![w[1]], biases));
    }
    let f = layers[0];
    let classes = *layers.last().unwrap();
    let x: Vec<f32> = (0..batch * f).map(|_| rng.uniform(0.0, 1.0) as f32).collect();
    let y: Vec<i32> = (0..batch).map(|_| rng.below(classes as u64) as i32).collect();
    let mut mask = vec![1.0f32; batch];
    for m in mask.iter_mut().take(masked) {
        *m = 0.0;
    }
    inputs.push(Tensor::f32(vec![batch, f], x));
    inputs.push(Tensor::i32(vec![batch], y));
    inputs.push(Tensor::f32(vec![batch], mask));
    inputs
}

/// Loss at the given inputs (the `grad_step` loss_sum output).
fn loss_at(be: &mut NativeBackend, call: &Call, inputs: &[Tensor]) -> f32 {
    let out = be.execute(call, inputs.to_vec()).expect("grad_step");
    out[out.len() - 2].scalar()
}

#[test]
fn gradients_match_finite_differences_per_layer() {
    let shapes = one_of(vec![
        vec![3usize, 2],
        vec![4, 3, 2],
        vec![5, 4, 3],
        vec![4, 3, 3, 2],
    ]);
    let gen = tuple2(shapes, tuple2(usize_range(1, 5), u64_range(0, 1 << 20)));
    forall("native grad == finite differences", &gen, |(layers, (batch, seed))| {
        let call = grad_call(layers);
        let mut be = NativeBackend::new();
        // one masked row when the batch allows, so padding neutrality
        // is part of the checked property
        let masked = usize::from(*batch > 1);
        let inputs = random_inputs(layers, *batch, masked, *seed);
        let analytic = be.execute(&call, inputs.clone()).expect("grad_step");
        let eps = 5e-3f32;
        for t in 0..call.param_tensors() {
            let n = inputs[t].len();
            for i in 0..n {
                let mut plus = inputs.clone();
                plus[t].as_f32_mut()[i] += eps;
                let mut minus = inputs.clone();
                minus[t].as_f32_mut()[i] -= eps;
                let out_plus = be.execute(&call, plus.clone()).expect("grad_step");
                let out_minus = be.execute(&call, minus.clone()).expect("grad_step");
                let got = analytic[t].as_f32()[i];
                // a relu kink inside [w−ε, w+ε] makes the loss only
                // piecewise-smooth there and the FD estimate meaningless;
                // detect it by the analytic gradient jumping across the
                // interval and skip the coordinate (smooth softmax
                // curvature moves it far less than this threshold)
                let (ga, gb) = (out_plus[t].as_f32()[i], out_minus[t].as_f32()[i]);
                if (ga - gb).abs() > 0.2 * (got.abs() + 0.05) {
                    continue;
                }
                let lp = out_plus[out_plus.len() - 2].scalar();
                let lm = out_minus[out_minus.len() - 2].scalar();
                let fd = (lp - lm) / (2.0 * eps);
                let tol = 5e-3 + 0.05 * got.abs().max(fd.abs());
                if (got - fd).abs() > tol {
                    eprintln!(
                        "layers {layers:?} batch {batch} seed {seed}: tensor {t} coord {i}: \
                         analytic {got} vs fd {fd}"
                    );
                    return false;
                }
            }
        }
        true
    });
}

/// ISSUE 5 property: the pooled execution path equals the serial path
/// bit for bit — any shape, any batch, any thread count, both
/// functions. This is the invariant that lets the trainer ≡ 1-shard
/// cluster ≡ ParamServer replay equivalences survive parallel compute.
#[test]
fn pooled_matmul_equals_serial_bit_for_bit_across_shapes_and_threads() {
    let shapes = one_of(vec![
        vec![9usize, 8, 3],
        vec![33, 48, 5],
        vec![96, 64, 2],
        vec![48, 32, 16, 4],
        vec![5, 2],
    ]);
    let gen = tuple2(
        shapes,
        tuple2(usize_range(1, 96), tuple2(usize_range(2, 8), u64_range(0, 1 << 20))),
    );
    forall("pooled == serial, bit for bit", &gen, |(layers, (batch, (threads, seed)))| {
        let masked = usize::from(*batch > 1);
        let inputs = random_inputs(layers, *batch, masked, *seed);
        let mut serial = NativeBackend::with_threads(1);
        let mut pooled = NativeBackend::with_threads(*threads);
        for function in [Function::GradStep, Function::EvalBatch] {
            let call = Call::new(function, "toy", layers);
            let want = serial.execute(&call, inputs.clone()).expect("serial");
            let got = pooled.execute(&call, inputs.clone()).expect("pooled");
            if want.len() != got.len() {
                return false;
            }
            for (x, y) in want.iter().zip(&got) {
                if x.dims != y.dims {
                    return false;
                }
                let same = x
                    .as_f32()
                    .iter()
                    .zip(y.as_f32())
                    .all(|(p, q)| p.to_bits() == q.to_bits());
                if !same {
                    eprintln!(
                        "layers {layers:?} batch {batch} threads {threads} seed {seed}: \
                         {function:?} diverged"
                    );
                    return false;
                }
            }
        }
        true
    });
}

/// ISSUE 5 acceptance: a seeded 10-step training run produces identical
/// parameters at every pool size. The layer is wide enough (648×64 at
/// batch 128) that the parallel tiles genuinely engage; `ci.sh` runs
/// this whole test binary under `MEL_THREADS=1` and `MEL_THREADS=4` so
/// the env-sized shared pool is exercised at both extremes as well.
#[test]
fn thread_count_determinism_of_seeded_10_step_run() {
    fn run(mut be: NativeBackend) -> (Vec<f32>, Vec<Vec<f32>>) {
        let layers = [648usize, 64, 2];
        let call = grad_call(&layers);
        let spec = DatasetSpec { total_samples: 128, ..DatasetSpec::pedestrian() };
        let ds = SyntheticDataset::generate(&spec, 128, 11);
        let idx: Vec<usize> = (0..128).collect();
        let (x, y) = ds.gather_f32(&idx);
        let xt = Tensor::f32(vec![128, 648], x);
        let yt = Tensor::i32(vec![128], y);
        let mt = Tensor::f32(vec![128], vec![1.0; 128]);
        let mut params = ParamSet::init(&layers, 5);
        let mut losses = Vec::new();
        for _ in 0..10 {
            let mut inputs = params.tensors.clone();
            inputs.push(xt.clone());
            inputs.push(yt.clone());
            inputs.push(mt.clone());
            let out = be.execute(&call, inputs).unwrap();
            losses.push(out[4].scalar());
            let grads: Vec<Tensor> = out[..4].to_vec();
            params.sgd_apply(&grads, 0.05, out[5].scalar());
        }
        (losses, params.tensors.iter().map(|t| t.as_f32().to_vec()).collect())
    }
    let (loss_1, params_1) = run(NativeBackend::with_threads(1));
    for threads in [2usize, 4, 8] {
        let (loss_n, params_n) = run(NativeBackend::with_threads(threads));
        for (a, b) in loss_1.iter().zip(&loss_n) {
            assert_eq!(a.to_bits(), b.to_bits(), "loss diverged at {threads} threads");
        }
        for (t, (a, b)) in params_1.iter().zip(&params_n).enumerate() {
            assert_eq!(a.len(), b.len());
            for (i, (p, q)) in a.iter().zip(b).enumerate() {
                assert_eq!(
                    p.to_bits(),
                    q.to_bits(),
                    "threads={threads}: param tensor {t} coord {i}: {p} vs {q}"
                );
            }
        }
    }
    // the shared (MEL_THREADS-sized) pool agrees with the dedicated ones
    let (loss_env, params_env) = run(NativeBackend::new());
    assert_eq!(
        loss_1.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
        loss_env.iter().map(|l| l.to_bits()).collect::<Vec<_>>()
    );
    assert_eq!(params_1, params_env);
}

#[test]
fn fully_masked_batch_has_zero_gradients_and_loss() {
    let layers = [4usize, 3, 2];
    let call = grad_call(&layers);
    let mut be = NativeBackend::new();
    let inputs = random_inputs(&layers, 3, 3, 7); // every row masked out
    let out = be.execute(&call, inputs).unwrap();
    for t in out.iter().take(4) {
        assert!(t.as_f32().iter().all(|&v| v == 0.0));
    }
    assert_eq!(out[4].scalar(), 0.0);
    assert_eq!(out[5].scalar(), 0.0);
}

#[test]
fn zero_params_pin_closed_form_loss() {
    // golden value: uniform logits ⇒ loss = n·ln C exactly (up to f32)
    for (layers, n) in [(vec![6usize, 4, 3], 9usize), (vec![5, 2], 4)] {
        let call = grad_call(&layers);
        let mut be = NativeBackend::new();
        let mut inputs = random_inputs(&layers, n, 0, 3);
        for t in inputs.iter_mut().take(2 * (layers.len() - 1)) {
            for v in t.as_f32_mut() {
                *v = 0.0;
            }
        }
        let classes = *layers.last().unwrap() as f32;
        let loss = loss_at(&mut be, &call, &inputs);
        assert!(
            (loss - n as f32 * classes.ln()).abs() < 1e-4,
            "layers {layers:?}: loss {loss}"
        );
    }
}

/// Ten full-batch SGD updates on a seeded synthetic batch: the loss
/// must strictly decrease at every step, and the whole trajectory must
/// be bit-for-bit reproducible (the "golden run" the next PR can pin
/// numbers against).
#[test]
fn seeded_sgd_run_descends_strictly_and_deterministically() {
    fn run() -> Vec<f32> {
        let layers = [648usize, 16, 2];
        let call = grad_call(&layers);
        let mut be = NativeBackend::new();
        let spec = DatasetSpec { total_samples: 64, ..DatasetSpec::pedestrian() };
        let ds = SyntheticDataset::generate(&spec, 64, 11);
        let idx: Vec<usize> = (0..64).collect();
        let (x, y) = ds.gather_f32(&idx);
        let xt = Tensor::f32(vec![64, 648], x);
        let yt = Tensor::i32(vec![64], y);
        let mt = Tensor::f32(vec![64], vec![1.0; 64]);
        let mut params = ParamSet::init(&layers, 5);
        let mut losses = Vec::new();
        for _ in 0..10 {
            let mut inputs = params.tensors.clone();
            inputs.push(xt.clone());
            inputs.push(yt.clone());
            inputs.push(mt.clone());
            let out = be.execute(&call, inputs).unwrap();
            losses.push(out[4].scalar() / out[5].scalar());
            let grads: Vec<Tensor> = out[..4].to_vec();
            // conservative lr: strict monotone descent needs the step
            // to stay well inside the curvature bound
            params.sgd_apply(&grads, 0.05, out[5].scalar());
        }
        losses
    }
    let losses = run();
    assert_eq!(losses.len(), 10);
    assert!(
        losses.windows(2).all(|w| w[1] < w[0]),
        "loss must strictly decrease over the 10-update run: {losses:?}"
    );
    assert!(
        losses[9] < 0.9 * losses[0],
        "10 full-batch steps should cut the loss measurably: {losses:?}"
    );
    // bit-exact determinism: the executor has no hidden state
    let again = run();
    for (a, b) in losses.iter().zip(&again) {
        assert_eq!(a.to_bits(), b.to_bits(), "{losses:?} vs {again:?}");
    }
}

/// Parameter-averaging exactness: average K model copies (eq. 5), run
/// one `grad_step` on the average, apply one SGD step — and compare
/// every number against the closed form on a **zero-hidden** model
/// (softmax regression). The copies are chosen so their weighted
/// average is exactly the all-zero parameter set, where uniform logits
/// make the gradient analytic: `∂L/∂W[j,c] = Σ_i x[i,j]·(1/C − 1[y_i=c])`,
/// `∂L/∂b[c] = Σ_i (1/C − 1[y_i=c])`, `loss = n·ln C`. This is the
/// cluster parameter server's aggregation + application step in one
/// golden-value test.
#[test]
fn averaging_copies_then_grad_step_matches_closed_form_on_zero_hidden_model() {
    let layers = [6usize, 3]; // no hidden layer: input → classes
    let (f, classes, n) = (6usize, 3usize, 5usize);

    // K = 3 copies whose weighted average cancels exactly: +a and −a at
    // equal weight, a zero set at double weight
    let constant = |v: f32| {
        let tensors = vec![
            Tensor::f32(vec![f, classes], vec![v; f * classes]),
            Tensor::f32(vec![classes], vec![v; classes]),
        ];
        ParamSet { tensors, layers: layers.to_vec() }
    };
    let avg = ParamSet::weighted_average(&[
        (1.0, constant(0.5)),
        (1.0, constant(-0.5)),
        (2.0, constant(0.0)),
    ]);
    for t in &avg.tensors {
        assert!(t.as_f32().iter().all(|&v| v == 0.0), "average must cancel exactly");
    }

    // seeded batch
    let mut rng = Pcg64::seeded(31);
    let x: Vec<f32> = (0..n * f).map(|_| rng.uniform(0.0, 1.0) as f32).collect();
    let y: Vec<i32> = (0..n).map(|_| rng.below(classes as u64) as i32).collect();
    let mut inputs = avg.tensors.clone();
    inputs.push(Tensor::f32(vec![n, f], x.clone()));
    inputs.push(Tensor::i32(vec![n], y.clone()));
    inputs.push(Tensor::f32(vec![n], vec![1.0; n]));
    let call = grad_call(&layers);
    let mut be = NativeBackend::new();
    let out = be.execute(&call, inputs).expect("grad_step");
    assert_eq!(out.len(), 4); // dW, db, loss_sum, weight_sum

    // closed form at zero parameters: uniform softmax p = 1/C
    let p = 1.0f64 / classes as f64;
    for j in 0..f {
        for c in 0..classes {
            let expected: f64 = (0..n)
                .map(|i| {
                    x[i * f + j] as f64 * (p - if y[i] as usize == c { 1.0 } else { 0.0 })
                })
                .sum();
            let got = out[0].as_f32()[j * classes + c] as f64;
            assert!(
                (got - expected).abs() < 1e-5,
                "dW[{j},{c}]: analytic {got} vs closed form {expected}"
            );
        }
    }
    for c in 0..classes {
        let expected: f64 =
            (0..n).map(|i| p - if y[i] as usize == c { 1.0 } else { 0.0 }).sum();
        let got = out[1].as_f32()[c] as f64;
        assert!((got - expected).abs() < 1e-5, "db[{c}]: {got} vs {expected}");
    }
    let loss = out[2].scalar() as f64;
    assert!((loss - n as f64 * (classes as f64).ln()).abs() < 1e-4, "loss {loss}");
    assert_eq!(out[3].scalar(), n as f32);

    // one SGD step from the average: w ← 0 − (lr/n)·g, every coordinate
    let mut stepped = avg.clone();
    let grads: Vec<Tensor> = out[..2].to_vec();
    let lr = 0.1f32;
    stepped.sgd_apply(&grads, lr, n as f32);
    for (t, g) in stepped.tensors.iter().zip(&grads) {
        for (w, gv) in t.as_f32().iter().zip(g.as_f32()) {
            let expected = -(lr / n as f32) * gv;
            assert!(
                (w - expected).abs() < 1e-7,
                "sgd step: {w} vs closed form {expected}"
            );
        }
    }
}

/// Run a fused step; return `(new params, loss_sum, weight_sum)`.
fn fused_out(be: &mut NativeBackend, call: &Call, inputs: &[Tensor], lr: f32) -> (Vec<Tensor>, f32, f32) {
    let mut v = inputs.to_vec();
    v.push(Tensor::scalar_f32(lr));
    let out = be.execute(call, v).expect("fused_step");
    let np = call.param_tensors();
    let loss = out[np].scalar();
    let weight = out[np + 1].scalar();
    (out, loss, weight)
}

/// ISSUE 6: finite differences re-run against the **fused** step. The
/// analytic gradient is recovered from the in-call SGD update itself
/// (`dp = (p − p')·max(weight,1)/lr`), so this checks the fused
/// backward *and* the fused apply arithmetic end to end; the loss
/// evaluations for the FD quotient also come from fused calls. The
/// relu-kink detection of the original property is kept verbatim.
#[test]
fn fused_step_gradients_match_finite_differences() {
    let lr = 0.5f32;
    for (layers, batch, seed) in [
        (vec![4usize, 3, 2], 4usize, 11u64),
        (vec![5, 4, 3], 3, 23),
        (vec![3, 2], 5, 47),
    ] {
        let call = Call::new(Function::FusedStep, "toy", &layers);
        let mut be = NativeBackend::new();
        let masked = usize::from(batch > 1);
        let inputs = random_inputs(&layers, batch, masked, seed);
        let np = call.param_tensors();
        let (out, _, weight) = fused_out(&mut be, &call, &inputs, lr);
        let scale = weight.max(1.0) / lr;
        // recover analytic grads from one application's parameter delta
        let recover = |out: &[Tensor], t: usize, i: usize, base: &[Tensor]| -> f32 {
            (base[t].as_f32()[i] - out[t].as_f32()[i]) * scale
        };
        let eps = 5e-3f32;
        for t in 0..np {
            for i in 0..inputs[t].len() {
                let mut plus = inputs.clone();
                plus[t].as_f32_mut()[i] += eps;
                let mut minus = inputs.clone();
                minus[t].as_f32_mut()[i] -= eps;
                let (out_p, lp, _) = fused_out(&mut be, &call, &plus, lr);
                let (out_m, lm, _) = fused_out(&mut be, &call, &minus, lr);
                let got = recover(&out, t, i, &inputs);
                // relu-kink detection, identical to the grad_step test
                let ga = recover(&out_p, t, i, &plus);
                let gb = recover(&out_m, t, i, &minus);
                if (ga - gb).abs() > 0.2 * (got.abs() + 0.05) {
                    continue;
                }
                let fd = (lp - lm) / (2.0 * eps);
                let tol = 5e-3 + 0.05 * got.abs().max(fd.abs());
                assert!(
                    (got - fd).abs() < tol,
                    "layers {layers:?} seed {seed}: tensor {t} coord {i}: \
                     fused-recovered {got} vs fd {fd}"
                );
            }
        }
    }
}

/// ISSUE 6: the finite-difference property holds on the quantized path
/// too. At 24 bits the fake-quantize grid step (absmax/(2²³−1) ≈ 1e−7
/// here) is orders of magnitude below the FD epsilon, so the snapped
/// loss is still FD-smooth while every forward/backward genuinely runs
/// the quantized code. Kink detection kept.
#[test]
fn quantized_gradients_match_finite_differences_at_24_bits() {
    for (layers, batch, seed) in
        [(vec![4usize, 3, 2], 4usize, 5u64), (vec![5, 4, 3], 3, 17)]
    {
        let call = Call::new(Function::GradStep, "toy", &layers).with_precision(24);
        let mut be = NativeBackend::new();
        let inputs = random_inputs(&layers, batch, usize::from(batch > 1), seed);
        let analytic = be.execute(&call, inputs.clone()).expect("grad_step");
        let eps = 5e-3f32;
        for t in 0..call.param_tensors() {
            for i in 0..inputs[t].len() {
                let mut plus = inputs.clone();
                plus[t].as_f32_mut()[i] += eps;
                let mut minus = inputs.clone();
                minus[t].as_f32_mut()[i] -= eps;
                let out_p = be.execute(&call, plus).expect("grad_step");
                let out_m = be.execute(&call, minus).expect("grad_step");
                let got = analytic[t].as_f32()[i];
                let (ga, gb) = (out_p[t].as_f32()[i], out_m[t].as_f32()[i]);
                if (ga - gb).abs() > 0.2 * (got.abs() + 0.05) {
                    continue;
                }
                let lp = out_p[out_p.len() - 2].scalar();
                let lm = out_m[out_m.len() - 2].scalar();
                let fd = (lp - lm) / (2.0 * eps);
                let tol = 5e-3 + 0.05 * got.abs().max(fd.abs());
                assert!(
                    (got - fd).abs() < tol,
                    "layers {layers:?} seed {seed}: tensor {t} coord {i}: \
                     quantized analytic {got} vs fd {fd}"
                );
            }
        }
    }
}

/// ISSUE 6: a τ-step **fused** training run is bit-for-bit the unfused
/// `grad_step` + accumulate + `sgd_apply` run, serial and pooled alike
/// — the invariant that lets `local_training` upgrade its native
/// single-chunk loop to fused calls without moving any equivalence.
#[test]
fn fused_multi_step_run_is_bit_equal_to_unfused_at_1_and_4_threads() {
    let layers = [96usize, 48, 4];
    let batch = 64;
    let lr = 0.05f32;
    let tau = 5;
    for threads in [1usize, 4] {
        let inputs = random_inputs(&layers, batch, 2, 99);
        let np = 2 * (layers.len() - 1);
        let batch_tensors = &inputs[np..];
        let gcall = grad_call(&layers);
        let fcall = Call::new(Function::FusedStep, "toy", &layers);
        // unfused replay
        let mut be = NativeBackend::with_threads(threads);
        let mut unfused: Vec<Tensor> = inputs[..np].to_vec();
        for _ in 0..tau {
            let mut v = unfused.clone();
            v.extend(batch_tensors.iter().cloned());
            let out = be.execute(&gcall, v).unwrap();
            let weight = out[np + 1].scalar();
            let scale = -lr / weight.max(1.0);
            // the exact unfused arithmetic: zeroed accumulator +
            // axpy(1.0, g), then the scaled apply
            for (p, g) in unfused.iter_mut().zip(&out[..np]) {
                let mut acc = Tensor::zeros_f32(g.dims.clone());
                acc.axpy(1.0, g);
                p.axpy(scale, &acc);
            }
        }
        // fused run
        let mut fused: Vec<Tensor> = inputs[..np].to_vec();
        for _ in 0..tau {
            let mut v = fused.clone();
            v.extend(batch_tensors.iter().cloned());
            v.push(Tensor::scalar_f32(lr));
            let out = be.execute(&fcall, v).unwrap();
            for (p, np_t) in fused.iter_mut().zip(out) {
                *p = np_t;
            }
        }
        for (t, (a, b)) in unfused.iter().zip(&fused).enumerate() {
            for (i, (p, q)) in a.as_f32().iter().zip(b.as_f32()).enumerate() {
                assert_eq!(
                    p.to_bits(),
                    q.to_bits(),
                    "threads={threads}: tensor {t} coord {i}: {p} vs {q}"
                );
            }
        }
    }
}

/// ISSUE 6: the quantized paths (real int8 at 8 bits, grid fake-quant
/// at 16) are deterministic — identical bits run-to-run and at any
/// thread count — exactly like the f32 path.
#[test]
fn quantized_execution_is_deterministic_and_thread_invariant() {
    let layers = [48usize, 32, 4];
    let inputs = random_inputs(&layers, 40, 1, 7);
    for bits in [8u32, 16] {
        for function in [Function::GradStep, Function::EvalBatch] {
            let call = Call::new(function, "toy", &layers).with_precision(bits);
            let mut serial = NativeBackend::with_threads(1);
            let a = serial.execute(&call, inputs.clone()).unwrap();
            let b = serial.execute(&call, inputs.clone()).unwrap();
            for (x, y) in a.iter().zip(&b) {
                for (p, q) in x.as_f32().iter().zip(y.as_f32()) {
                    assert_eq!(p.to_bits(), q.to_bits(), "bits={bits} {function:?} rerun");
                }
            }
            for threads in [2usize, 4] {
                let mut pooled = NativeBackend::with_threads(threads);
                let c = pooled.execute(&call, inputs.clone()).unwrap();
                for (x, y) in a.iter().zip(&c) {
                    for (p, q) in x.as_f32().iter().zip(y.as_f32()) {
                        assert_eq!(
                            p.to_bits(),
                            q.to_bits(),
                            "bits={bits} {function:?} diverged at {threads} threads"
                        );
                    }
                }
            }
        }
    }
}

/// ISSUE 6: quantized-vs-f32 divergence is bounded by the grid. The
/// tolerances derive from the per-tensor step (`absmax/levels`, see
/// `kernels::grid_step`): operands here have absmax ≲ 1, so the 8-bit
/// grid moves each of them by ≤ ~0.004 and the 16-bit grid by ≤ ~2e−5;
/// the loss bounds below allow a generous accumulation factor across
/// the two layers.
#[test]
fn quantized_loss_stays_within_grid_derived_bound_of_f32() {
    let layers = [24usize, 16, 3];
    let batch = 32;
    let inputs = random_inputs(&layers, batch, 0, 13);
    let mut be = NativeBackend::new();
    let f32_loss = loss_at(&mut be, &grad_call(&layers), &inputs);
    for (bits, rel, abs) in [(8u32, 0.05f32, 0.5f32), (16, 0.005, 0.05)] {
        let call = grad_call(&layers).with_precision(bits);
        let q_loss = loss_at(&mut be, &call, &inputs);
        assert!(q_loss.is_finite());
        let tol = rel * f32_loss.abs() + abs;
        assert!(
            (q_loss - f32_loss).abs() <= tol,
            "bits={bits}: quantized loss {q_loss} vs f32 {f32_loss} (tol {tol})"
        );
    }
    // ≥ 32 bits must not merely be *close* — it is the identical path
    let c64 = grad_call(&layers).with_precision(64);
    let same = loss_at(&mut be, &c64, &inputs);
    assert_eq!(same.to_bits(), f32_loss.to_bits());
}

#[test]
fn chunked_gradient_accumulation_equals_single_batch() {
    // sum-form losses: grad(batch) == grad(first half) + grad(second
    // half) — the invariant the coordinator's chunk accumulation needs
    let layers = [5usize, 4, 2];
    let call = grad_call(&layers);
    let mut be = NativeBackend::new();
    let inputs = random_inputs(&layers, 6, 0, 21);
    let full = be.execute(&call, inputs.clone()).unwrap();

    let np = call.param_tensors();
    let halves: Vec<Vec<Tensor>> = [(0usize, 3usize), (3, 6)]
        .iter()
        .map(|&(lo, hi)| {
            let mut h = inputs.clone();
            let mask: Vec<f32> =
                (0..6).map(|i| if i >= lo && i < hi { 1.0 } else { 0.0 }).collect();
            h[np + 2] = Tensor::f32(vec![6], mask);
            h
        })
        .collect();
    let a = be.execute(&call, halves[0].clone()).unwrap();
    let b = be.execute(&call, halves[1].clone()).unwrap();
    for t in 0..np {
        for (i, &fv) in full[t].as_f32().iter().enumerate() {
            let sum = a[t].as_f32()[i] + b[t].as_f32()[i];
            assert!(
                (fv - sum).abs() < 1e-4 * (1.0 + fv.abs()),
                "tensor {t} coord {i}: {fv} vs {sum}"
            );
        }
    }
    assert!((full[np].scalar() - (a[np].scalar() + b[np].scalar())).abs() < 1e-4);
    assert_eq!(a[np + 1].scalar() + b[np + 1].scalar(), full[np + 1].scalar());
}
