//! Integration: the full trainer loop (allocate → dispatch → real local
//! training → aggregate → evaluate) on a miniature cloudlet, executed
//! end to end through the hermetic native backend — no `make artifacts`,
//! no `pjrt` feature, no skips.
//!
//! The scenarios keep the paper's *timing* coefficients (so allocations
//! and τ match the published scale) while the executed graph uses a
//! shrunken hidden layer (`ModelSpec::with_hidden`) to keep real
//! compute fast in debug builds.

use mel::alloc::Policy;
use mel::coordinator::{Orchestrator, TrainConfig};
use mel::runtime::{BackendChoice, BackendKind};
use mel::scenario::{CloudletConfig, Scenario};

fn tiny_scenario(k: usize, d: usize, seed: u64) -> Scenario {
    let mut cfg = CloudletConfig::pedestrian(k);
    cfg.model = cfg.model.with_hidden(&[8]); // small real graph, paper timing
    cfg.dataset.total_samples = d; // shrink per-cycle data for CPU speed
    Scenario::random_cloudlet(&cfg, seed)
}

fn cfg(policy: Policy, cycles: usize) -> TrainConfig {
    TrainConfig {
        // T=2s keeps τ ≈ 15 for a K=3 cloudlet: large enough to show the
        // adaptive gain, small enough that local models stay in the same
        // basin so eq.(5) averaging helps (τ ≫ 100 exhibits the
        // "deviating gradients" effect of [13] — exercised separately in
        // the e2e example).
        policy,
        t_total: 2.0,
        cycles,
        lr: 0.05,
        seed: 7,
        eval_samples: 96,
        backend: BackendChoice::Native,
        dispatch_threads: 3,
        ..TrainConfig::default()
    }
}

#[test]
fn orchestrator_trains_and_loss_decreases() {
    let mut orch = Orchestrator::new(tiny_scenario(3, 240, 1), cfg(Policy::Analytical, 5))
        .expect("native trainer init");
    assert_eq!(orch.backend_kind(), BackendKind::Native);
    let (loss0, _acc0) = orch.evaluate().unwrap();
    let outcomes = orch.train().unwrap();
    assert_eq!(outcomes.len(), 5);
    let last = outcomes.last().unwrap();
    assert!(
        last.loss < loss0 * 0.9,
        "loss should drop: {loss0} → {}",
        last.loss
    );
    assert!(last.accuracy > 0.6, "accuracy {}", last.accuracy);
    // every cycle met its deadline in simulated time
    for o in &outcomes {
        assert!(o.makespan <= 2.0 + 1e-6);
        assert!(o.tau >= 1);
        assert_eq!(o.batches.iter().sum::<usize>(), 240);
    }
    // simulated clock advanced cycle × T
    assert!((orch.sim_time() - 5.0 * 2.0).abs() < 1e-9);
    // metrics populated
    assert_eq!(orch.metrics.counter("cycles"), 5);
    assert_eq!(orch.metrics.series("loss_vs_simtime").len(), 5);
    assert_eq!(orch.metrics.series("acc_vs_simtime").len(), 5);
}

#[test]
fn adaptive_gets_more_iterations_than_eta_same_clock() {
    let s = tiny_scenario(4, 384, 3);
    let mut o_ada =
        Orchestrator::new(s.clone(), cfg(Policy::Analytical, 1)).expect("init adaptive");
    let mut o_eta = Orchestrator::new(s, cfg(Policy::Eta, 1)).expect("init eta");
    let a = o_ada.run_cycle(0).unwrap();
    let e = o_eta.run_cycle(0).unwrap();
    assert!(
        a.tau > e.tau,
        "adaptive τ {} should beat ETA τ {} under the same T",
        a.tau,
        e.tau
    );
}

#[test]
fn aggregation_weights_match_batches() {
    // single cycle with wildly heterogeneous batches: the global params
    // must move (aggregation happened) and stay finite
    let mut orch =
        Orchestrator::new(tiny_scenario(3, 192, 5), cfg(Policy::Analytical, 1)).unwrap();
    let before = orch.params().clone();
    orch.run_cycle(0).unwrap();
    let after = orch.params();
    let dist = before.distance2(after);
    assert!(dist > 0.0, "parameters did not move");
    for t in &after.tensors {
        assert!(t.as_f32().iter().all(|v| v.is_finite()));
    }
}

#[test]
fn mnist_arch_trains_one_cycle() {
    let mut s_cfg = CloudletConfig::mnist(2);
    s_cfg.model = s_cfg.model.with_hidden(&[12]);
    s_cfg.dataset.total_samples = 192;
    let s = Scenario::random_cloudlet(&s_cfg, 2);
    let mut c = cfg(Policy::UbSai, 1);
    c.t_total = 5.0;
    let mut orch = Orchestrator::new(s, c).unwrap();
    let o = orch.run_cycle(0).unwrap();
    assert!(o.tau >= 1);
    assert!(o.loss.is_finite());
}

#[test]
fn stragglers_dropped_under_fading_with_stale_allocation() {
    // Stale allocation (solved once) + heavy per-cycle fading ⇒ some
    // cycles miss deadlines; drop_stragglers keeps training alive.
    let mut c = cfg(Policy::Analytical, 6);
    c.shadow_sigma_db = 8.0;
    c.rayleigh = true;
    c.drop_stragglers = true;
    c.reallocate_each_cycle = false;
    let mut orch = Orchestrator::new(tiny_scenario(3, 192, 11), c).unwrap();
    let outcomes = orch.train().unwrap();
    assert_eq!(outcomes.len(), 6);
    // with 8 dB shadowing swings, at least one straggler is expected;
    // training still completes and produces finite losses either way
    assert!(outcomes.iter().all(|o| o.loss.is_finite()));
    println!("stragglers dropped: {}", orch.stragglers_dropped());
}

#[test]
fn reallocation_each_cycle_avoids_straggler_drops() {
    // Re-solving per cycle adapts batches to the faded channels, so no
    // deadline misses even without drop_stragglers.
    let mut c = cfg(Policy::UbSai, 4);
    c.shadow_sigma_db = 8.0;
    c.rayleigh = true;
    c.drop_stragglers = false;
    c.reallocate_each_cycle = true;
    let mut orch = Orchestrator::new(tiny_scenario(3, 192, 13), c).unwrap();
    let outcomes = orch.train().unwrap();
    assert_eq!(outcomes.len(), 4);
    assert_eq!(orch.stragglers_dropped(), 0);
}

#[test]
fn forcing_pjrt_without_feature_is_a_clean_error() {
    if mel::runtime::pjrt_available() {
        return; // on a pjrt box the forced path actually works
    }
    let mut c = cfg(Policy::Analytical, 1);
    c.backend = BackendChoice::Pjrt;
    let err = Orchestrator::new(tiny_scenario(2, 64, 1), c).unwrap_err();
    let msg = format!("{err}");
    // the message must name the real problem (feature/artifacts), not
    // pretend the engine is unusable — the native backend exists
    assert!(msg.contains("pjrt") || msg.contains("artifacts"), "{msg}");
}

#[test]
fn auto_backend_trains_without_artifacts() {
    // BackendChoice::Auto on a box without artifacts = native; the full
    // loop must run, not skip and not error
    let mut c = cfg(Policy::Eta, 1);
    c.backend = BackendChoice::Auto;
    let mut orch = Orchestrator::new(tiny_scenario(2, 96, 9), c).unwrap();
    let o = orch.run_cycle(0).unwrap();
    assert!(o.loss.is_finite());
    assert!(o.tau >= 1);
}
