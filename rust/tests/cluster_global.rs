//! Equivalence- and property-test harness of the cluster-level
//! parameter server (`mel::cluster::ParamServer`):
//!
//! 1. **Refactor pin** — a 1-shard per-update ParamServer replay is
//!    bit-for-bit equal to the single-cloudlet `Trainer`: the update
//!    timeline (vs the orchestrator core), the final parameters, and
//!    the per-cycle loss/accuracy values all match exactly. This is
//!    what guarantees the extracted shared application path
//!    (`coordinator::apply`) cannot drift between the two tiers.
//! 2. **Round-aggregation properties** — weighted global rounds
//!    conserve the total batch share (zero discount ⇒ weights are
//!    batch shares, summing over rounds to every aggregated update's
//!    batch) and are invariant under shard merge order, for 2- and
//!    4-shard configs under churn.
//! 3. **Staleness-discount monotonicity** — a higher discount never
//!    increases a stale update's applied norm (pure factor and full
//!    end-to-end replay).

use mel::alloc::Policy;
use mel::cluster::{
    staleness_factor, Cluster, ClusterConfig, ParamServer, ParamServerConfig,
};
use mel::coordinator::{ParamSet, TrainConfig, Trainer};
use mel::orchestrator::{Mode, Orchestrator, OrchestratorConfig, UpdateRecord};
use mel::scenario::{
    AggregationMode, ChurnTrace, CloudletConfig, ClusterSpec, GlobalAggSpec, Scenario, ShardSpec,
};

const T: f64 = 2.0;
const CYCLES: usize = 3;
const LR: f32 = 0.05;
const EVAL: usize = 48;
const SEED: u64 = 42;

/// Debug-build-friendly cloudlet: paper timing constants drive the
/// allocation while the executed graph uses a shrunken hidden layer.
fn tiny_cloudlet(k: usize, d: usize) -> CloudletConfig {
    let mut c = CloudletConfig::pedestrian(k);
    c.model = c.model.with_hidden(&[8]);
    c.dataset.total_samples = d;
    c
}

fn one_shard_spec(ccfg: &CloudletConfig) -> ClusterSpec {
    ClusterSpec {
        shards: vec![ShardSpec {
            cloudlet: ccfg.clone(),
            seed_offset: 0,
            churn: ChurnTrace::default(),
            population: None,
        }],
        global: Default::default(),
    }
}

fn assert_params_bit_equal(a: &ParamSet, b: &ParamSet, what: &str) {
    assert_eq!(a.tensors.len(), b.tensors.len(), "{what}: tensor count");
    for (i, (ta, tb)) in a.tensors.iter().zip(&b.tensors).enumerate() {
        assert_eq!(ta.dims, tb.dims, "{what}: tensor {i} dims");
        for (j, (x, y)) in ta.as_f32().iter().zip(tb.as_f32()).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{what}: tensor {i} coord {j}: {x} vs {y}"
            );
        }
    }
}

#[test]
fn one_shard_per_update_replay_matches_trainer_bit_for_bit() {
    let ccfg = tiny_cloudlet(2, 96);

    // --- reference: the single-cloudlet trainer, real training
    let scenario = Scenario::random_cloudlet(&ccfg, SEED);
    let tcfg = TrainConfig {
        policy: Policy::Analytical,
        t_total: T,
        cycles: CYCLES,
        lr: LR,
        seed: SEED,
        eval_samples: EVAL,
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(scenario, tcfg).expect("native engine");
    let outcomes = trainer.train().expect("feasible tiny pedestrian run");
    assert_eq!(outcomes.len(), CYCLES);

    // --- the cluster timing run (1 shard, zero churn)
    let spec = one_shard_spec(&ccfg);
    let cluster = Cluster::new(
        spec.clone(),
        ClusterConfig {
            policy: Policy::Analytical,
            mode: Mode::Sync,
            t_total: T,
            cycles: CYCLES,
            seed: SEED,
            ..ClusterConfig::default()
        },
    );
    let report = cluster.run().expect("feasible cluster run");

    // --- update timeline ≡ single-cloudlet orchestrator, bit-for-bit
    let mut orch = Orchestrator::new(
        Scenario::random_cloudlet(&ccfg, SEED),
        OrchestratorConfig {
            mode: Mode::Sync,
            policy: Policy::Analytical,
            t_total: T,
            cycles: CYCLES,
            seed: SEED,
            ..OrchestratorConfig::default()
        },
    );
    let single = orch.run().expect("feasible orchestrator run");
    let mut ref_sorted = single.updates.clone();
    ref_sorted.sort_by(|a, b| a.uploaded_at.total_cmp(&b.uploaded_at));
    assert_eq!(report.updates.len(), ref_sorted.len());
    for ((shard, a), b) in report.updates.iter().zip(&ref_sorted) {
        assert_eq!(*shard, 0);
        assert_eq!(a.learner, b.learner);
        assert_eq!(a.dispatched_at.to_bits(), b.dispatched_at.to_bits(), "dispatch instants");
        assert_eq!(a.uploaded_at.to_bits(), b.uploaded_at.to_bits(), "upload instants");
        assert_eq!(a.tau, b.tau);
        assert_eq!(a.batch, b.batch);
        assert_eq!(a.staleness, b.staleness);
        assert_eq!(a.missed_deadline, b.missed_deadline);
    }

    // --- per-update ParamServer replay reproduces the trainer exactly
    let ps_cfg = ParamServerConfig {
        aggregation: AggregationMode::PerUpdate,
        lr: LR,
        seed: SEED,
        eval_samples: EVAL,
        ..ParamServerConfig::default()
    };
    let mut ps = ParamServer::new(&spec, ps_cfg).expect("native engine");
    let global = ps.replay(&report.updates).expect("replay");
    // every barrier cohort applied once, every update's gradient entered
    assert_eq!(global.applies as usize, CYCLES);
    assert_eq!(global.updates_replayed as usize, report.updates.len());
    // final parameters: bit-for-bit
    assert_params_bit_equal(trainer.params(), &global.params, "1-shard replay");
    // per-cycle loss/accuracy: bit-for-bit (same eval set, same params)
    assert_eq!(global.acc_series.len(), outcomes.len());
    assert_eq!(global.loss_series.len(), outcomes.len());
    for (o, ((_, acc), (_, loss))) in
        outcomes.iter().zip(global.acc_series.iter().zip(&global.loss_series))
    {
        assert_eq!(o.accuracy.to_bits(), acc.to_bits(), "cycle {} accuracy", o.cycle);
        assert_eq!(o.loss.to_bits(), loss.to_bits(), "cycle {} loss", o.cycle);
    }
    assert_eq!(global.final_accuracy.to_bits(), outcomes.last().unwrap().accuracy.to_bits());
}

/// A `shards`-way cluster of tiny cloudlets, synthetic churn per shard,
/// rounds-mode aggregation knobs in the spec.
fn churny_spec(shards: usize) -> ClusterSpec {
    let ccfg = tiny_cloudlet(3, 96);
    ClusterSpec {
        shards: (0..shards)
            .map(|i| ShardSpec {
                cloudlet: ccfg.clone(),
                seed_offset: i as u64,
                churn: ChurnTrace::default(),
                population: None,
            })
            .collect(),
        global: GlobalAggSpec {
            aggregation: AggregationMode::Rounds,
            round_period_s: T,
            staleness_discount: 0.0,
            ..GlobalAggSpec::default()
        },
    }
    .with_synthetic_churn(CYCLES as f64 * T, 1, SEED)
}

#[test]
fn round_aggregation_conserves_batch_share_and_is_merge_order_invariant() {
    for shards in [2usize, 4] {
        let spec = churny_spec(shards);
        let cluster = Cluster::new(
            spec.clone(),
            ClusterConfig {
                policy: Policy::Analytical,
                mode: Mode::Async,
                t_total: T,
                cycles: CYCLES,
                seed: SEED,
                ..ClusterConfig::default()
            },
        );
        let report = cluster.run().expect("feasible churny run");
        assert!(!report.updates.is_empty());
        // churn actually happened somewhere in the cluster
        assert!(report.shards.iter().any(|s| s.joins + s.departs > 0), "no churn at {shards}");

        let ps_cfg = || ParamServerConfig {
            lr: LR,
            eval_samples: EVAL,
            drop_stragglers: true,
            ..ParamServerConfig::from_spec(&spec.global, SEED)
        };
        let mut ps = ParamServer::new(&spec, ps_cfg()).expect("native engine");
        let g = ps.replay(&report.updates).expect("replay");
        assert!(!g.rounds.is_empty());

        // conservation: with zero staleness discount every round's
        // applied weight IS its batch share, and the shares sum to the
        // total batch volume of every aggregated update
        let mut total_share = 0.0;
        for r in &g.rounds {
            assert_eq!(
                r.weight, r.batch_share,
                "{shards} shards, round {}: zero discount must conserve weights",
                r.index
            );
            total_share += r.batch_share;
        }
        let expected: f64 = report
            .updates
            .iter()
            .filter(|(_, u)| !u.missed_deadline)
            .map(|(_, u)| u.batch as f64)
            .sum();
        assert_eq!(total_share, expected, "{shards} shards: batch share not conserved");

        // permutation invariance: the merged stream's order must not
        // change the replayed global model by a single bit
        let mut reversed = report.updates.clone();
        reversed.reverse();
        let mut shard_desc = report.updates.clone();
        shard_desc.sort_by_key(|(s, _)| usize::MAX - *s);
        for (name, perm) in [("reversed", reversed), ("shard-descending", shard_desc)] {
            let mut ps2 = ParamServer::new(&spec, ps_cfg()).expect("native engine");
            let g2 = ps2.replay(&perm).expect("replay permuted stream");
            assert_eq!(g2.updates_replayed, g.updates_replayed);
            assert_eq!(g2.applies, g.applies);
            assert_params_bit_equal(
                &g.params,
                &g2.params,
                &format!("{shards}-shard {name} merge order"),
            );
        }
    }
}

#[test]
fn higher_staleness_discount_never_increases_applied_norm() {
    // the pure factor is non-increasing in the discount and in staleness
    for s in [1u64, 2, 5, 17] {
        let mut prev = f64::INFINITY;
        for d in [0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 1.0] {
            let f = staleness_factor(d, s);
            assert!(f <= prev, "staleness {s}: factor must be non-increasing in the discount");
            assert!((0.0..=1.0).contains(&f));
            prev = f;
        }
    }

    // end to end: one stale update replayed under growing discounts
    // moves the global model by a non-increasing amount
    let ccfg = tiny_cloudlet(2, 96);
    let spec = one_shard_spec(&ccfg);
    let stale = vec![(
        0usize,
        UpdateRecord {
            learner: 0,
            dispatched_at: 0.0,
            uploaded_at: 1.0,
            tau: 2,
            batch: 16,
            staleness: 3,
            missed_deadline: false,
        },
    )];
    let init = ParamSet::init(&ccfg.model.layers, SEED ^ 0x1417);
    let mut prev_norm = f64::INFINITY;
    let mut first_norm = None;
    for discount in [0.0, 0.3, 0.7, 1.0] {
        let cfg = ParamServerConfig {
            staleness_discount: discount,
            lr: LR,
            seed: SEED,
            eval_samples: EVAL,
            ..ParamServerConfig::default()
        };
        let mut ps = ParamServer::new(&spec, cfg).expect("native engine");
        let g = ps.replay(&stale).expect("replay");
        let norm = g.params.distance2(&init);
        assert!(
            norm <= prev_norm,
            "discount {discount} increased the applied norm ({norm} > {prev_norm})"
        );
        first_norm.get_or_insert(norm);
        prev_norm = norm;
    }
    // the undiscounted apply really moved the model…
    assert!(first_norm.unwrap() > 0.0, "zero-discount apply must move the global model");
    // …and a full discount ignores the stale update entirely
    assert_eq!(prev_norm, 0.0, "full discount must leave the global model untouched");
}
