//! Non-perturbation and exporter-golden tests of the tracing plane
//! (`mel::trace`):
//!
//! 1. **Training is bit-for-bit identical with tracing on and off** —
//!    the recorder only *reads* simulation state and the wall clock, so
//!    a seeded real-training run must produce identical parameters,
//!    losses and timelines either way. `ci.sh` runs this whole binary
//!    at `MEL_THREADS=1` and `MEL_THREADS=4`, so the guarantee holds
//!    across compute-pool parallelism too.
//! 2. **A churning 2-shard cluster is bit-for-bit identical** — same
//!    property through the event-driven churn path (joins, departs,
//!    re-leases, straggler releases all emit trace events).
//! 3. **Exporter goldens** — the Chrome trace-event JSON re-parses with
//!    `mel::util::json` and its lease phase spans (`send`/`compute`/
//!    `upload`) nest inside their `lease` span; the per-lease budget
//!    CSV's `send + compute + upload + slack` columns sum to `T`
//!    (eq. (13)) on every row.
//!
//! Every test toggles the process-global trace flag, so they serialize
//! on one lock.

use std::sync::{Mutex, MutexGuard, OnceLock};

use mel::alloc::Policy;
use mel::cluster::{Cluster, ClusterConfig};
use mel::coordinator::{ParamSet, TrainConfig, Trainer};
use mel::orchestrator::{Mode, Orchestrator, OrchestratorConfig, UpdateRecord};
use mel::scenario::{CloudletConfig, ClusterSpec, Scenario};
use mel::trace::{self, Kind};
use mel::util::json::Json;

const T: f64 = 2.0;
const SEED: u64 = 7;

fn lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

/// Debug-build-friendly cloudlet: paper timing constants drive the
/// allocation while the executed graph uses a shrunken hidden layer.
fn tiny_cloudlet(k: usize, d: usize) -> CloudletConfig {
    let mut c = CloudletConfig::pedestrian(k);
    c.model = c.model.with_hidden(&[8]);
    c.dataset.total_samples = d;
    c
}

fn assert_params_bit_equal(a: &ParamSet, b: &ParamSet, what: &str) {
    assert_eq!(a.tensors.len(), b.tensors.len(), "{what}: tensor count");
    for (i, (ta, tb)) in a.tensors.iter().zip(&b.tensors).enumerate() {
        assert_eq!(ta.dims, tb.dims, "{what}: tensor {i} dims");
        for (j, (x, y)) in ta.as_f32().iter().zip(tb.as_f32()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: tensor {i} coord {j}: {x} vs {y}");
        }
    }
}

fn assert_updates_bit_equal(a: &[(usize, UpdateRecord)], b: &[(usize, UpdateRecord)]) {
    assert_eq!(a.len(), b.len(), "update counts");
    for (i, ((sa, ua), (sb, ub))) in a.iter().zip(b).enumerate() {
        assert_eq!(sa, sb, "update {i}: shard");
        assert_eq!(ua.learner, ub.learner, "update {i}: learner");
        assert_eq!(
            ua.dispatched_at.to_bits(),
            ub.dispatched_at.to_bits(),
            "update {i}: dispatch instant"
        );
        assert_eq!(
            ua.uploaded_at.to_bits(),
            ub.uploaded_at.to_bits(),
            "update {i}: upload instant"
        );
        assert_eq!(ua.tau, ub.tau, "update {i}: tau");
        assert_eq!(ua.batch, ub.batch, "update {i}: batch");
        assert_eq!(ua.staleness, ub.staleness, "update {i}: staleness");
        assert_eq!(ua.missed_deadline, ub.missed_deadline, "update {i}: miss flag");
    }
}

#[test]
fn training_is_bit_identical_with_tracing_on_and_off() {
    let _g = lock();
    let run = |traced: bool| {
        trace::set_enabled(traced);
        trace::clear();
        let ccfg = tiny_cloudlet(3, 96);
        let cfg = TrainConfig {
            policy: Policy::Analytical,
            t_total: T,
            cycles: 10,
            lr: 0.05,
            seed: SEED,
            eval_samples: 48,
            trace_spans: traced,
            ..TrainConfig::default()
        };
        let mut trainer =
            Trainer::new(Scenario::random_cloudlet(&ccfg, SEED), cfg).expect("native engine");
        let outcomes = trainer.train().expect("feasible tiny run");
        assert_eq!(outcomes.len(), 10);
        let events = trace::drain();
        if traced {
            // real training must populate the whole plane: leases,
            // solver spans, local-training spans, pool jobs
            for (cat, name) in
                [("lease", "lease"), ("alloc", "solve_flat"), ("train", "local_training")]
            {
                assert!(
                    events.iter().any(|e| e.cat == cat && e.name == name),
                    "traced run is missing a {cat}/{name} event"
                );
            }
        } else {
            assert!(events.is_empty(), "disabled tracing must record nothing");
        }
        trace::set_enabled(false);
        let sig: Vec<(u64, u64, u64, Vec<usize>, u64)> = outcomes
            .iter()
            .map(|o| {
                (o.loss.to_bits(), o.accuracy.to_bits(), o.tau, o.batches.clone(), o.makespan.to_bits())
            })
            .collect();
        (trainer.params().clone(), sig)
    };
    let (params_off, sig_off) = run(false);
    let (params_on, sig_on) = run(true);
    assert_eq!(sig_off, sig_on, "per-cycle outcomes must not shift by a bit under tracing");
    assert_params_bit_equal(&params_off, &params_on, "traced vs untraced training");
}

#[test]
fn churny_cluster_is_bit_identical_with_tracing_on_and_off() {
    let _g = lock();
    let spec = || {
        let mut s = ClusterSpec::uniform("pedestrian", 2, 3).expect("builtin task");
        for shard in &mut s.shards {
            shard.cloudlet.model = shard.cloudlet.model.with_hidden(&[8]);
            shard.cloudlet.dataset.total_samples = 96;
        }
        s.with_synthetic_churn(3.0 * T, 1, 9)
    };
    let run = |traced: bool| {
        trace::set_enabled(traced);
        trace::clear();
        let cluster = Cluster::new(
            spec(),
            ClusterConfig {
                policy: Policy::Analytical,
                mode: Mode::Async,
                t_total: T,
                cycles: 3,
                seed: SEED,
                trace_spans: traced,
                ..ClusterConfig::default()
            },
        );
        let report = cluster.run().expect("feasible churny run");
        assert!(!report.updates.is_empty());
        let events = trace::drain();
        if traced {
            assert!(!events.is_empty(), "traced churny cluster recorded nothing");
        } else {
            assert!(events.is_empty(), "disabled tracing must record nothing");
        }
        trace::set_enabled(false);
        report
    };
    let off = run(false);
    let on = run(true);
    assert_updates_bit_equal(&off.updates, &on.updates);
    assert_eq!(off.deadline_misses, on.deadline_misses);
    assert_eq!(off.releases, on.releases);
    assert_eq!(off.updates_applied, on.updates_applied);
}

#[test]
fn exporters_chrome_json_parses_and_budget_csv_sums_to_t() {
    let _g = lock();
    trace::set_enabled(true);
    trace::clear();
    let ccfg = tiny_cloudlet(3, 96);
    let mut orch = Orchestrator::new(
        Scenario::random_cloudlet(&ccfg, 42),
        OrchestratorConfig {
            mode: Mode::Sync,
            policy: Policy::Analytical,
            t_total: T,
            cycles: 2,
            seed: 42,
            ..OrchestratorConfig::default()
        },
    );
    orch.run().expect("feasible orchestrator run");
    let events = trace::drain();
    trace::set_enabled(false);

    let leases: Vec<_> =
        events.iter().filter(|e| e.name == "lease" && e.kind == Kind::Span).collect();
    assert_eq!(leases.len(), 2 * 3, "one lease span per learner per cycle");

    // --- budget CSV: every row's budget terms sum to T exactly (slack
    // is defined as the remainder, eq. (13) fixes the other three)
    let csv = mel::trace::export::budget_csv(&events, T);
    let mut lines = csv.lines();
    assert_eq!(
        lines.next().unwrap(),
        "shard,learner,dispatch_s,tau,d,send_s,compute_s,upload_s,slack_s,t_total,on_time"
    );
    let mut rows = 0;
    for line in lines {
        let cols: Vec<&str> = line.split(',').collect();
        assert_eq!(cols.len(), 11, "malformed row {line:?}");
        let send: f64 = cols[5].parse().unwrap();
        let comp: f64 = cols[6].parse().unwrap();
        let up: f64 = cols[7].parse().unwrap();
        let slack: f64 = cols[8].parse().unwrap();
        let t_total: f64 = cols[9].parse().unwrap();
        assert!(
            (send + comp + up + slack - t_total).abs() < 1e-6,
            "budget terms must sum to T: {line:?}"
        );
        assert_eq!(cols[10], "true", "this feasible run has no late lease: {line:?}");
        rows += 1;
    }
    assert_eq!(rows, leases.len(), "one budget row per lease span");

    // --- Chrome trace JSON: round-trips through util::json, and the
    // lease phase spans nest inside their lease span on each track
    let text = mel::trace::export::chrome_trace(&events).to_string();
    let back = Json::parse(&text).expect("chrome trace JSON parses");
    let evs = back.get("traceEvents").unwrap().as_arr().unwrap();
    let name_of = |e: &Json| e.get("name").unwrap().as_str().unwrap().to_string();
    let ph_of = |e: &Json| e.get("ph").unwrap().as_str().unwrap().to_string();
    assert!(
        evs.iter().any(|e| ph_of(e) == "M" && name_of(e) == "process_name"),
        "missing process_name metadata"
    );
    let track = |e: &Json| -> (f64, f64) {
        (e.get("pid").unwrap().as_f64().unwrap(), e.get("tid").unwrap().as_f64().unwrap())
    };
    let span_range = |e: &Json| -> (f64, f64) {
        let ts = e.get("ts").unwrap().as_f64().unwrap();
        (ts, ts + e.get("dur").unwrap().as_f64().unwrap())
    };
    let lease_spans: Vec<_> =
        evs.iter().filter(|e| ph_of(e) == "X" && name_of(e) == "lease").collect();
    assert_eq!(lease_spans.len(), leases.len());
    let mut phases = 0;
    for e in evs {
        let ph = ph_of(e);
        let name = name_of(e);
        if ph != "X" || !matches!(name.as_str(), "send" | "compute" | "upload") {
            continue;
        }
        let (lo, hi) = span_range(e);
        let parent = lease_spans.iter().any(|l| {
            let (plo, phi) = span_range(l);
            track(l) == track(e) && plo <= lo + 0.5 && hi <= phi + 0.5
        });
        assert!(parent, "{name} span at {lo}..{hi}us has no enclosing lease span");
        phases += 1;
    }
    assert_eq!(phases, 3 * leases.len(), "send+compute+upload per lease");

    // --- Prometheus exposition sanity on the run's metrics
    let prom = orch.metrics.to_prometheus();
    assert!(prom.contains("# TYPE mel_tau gauge"), "missing tau gauge:\n{prom}");
    assert!(prom.contains("mel_makespan_count"), "missing makespan summary:\n{prom}");
}

#[test]
fn live_plane_spans_are_recorded_and_the_sim_offset_is_restored() {
    let _g = lock();
    trace::set_enabled(true);
    trace::clear();
    // a rebased clock left by whatever this thread traced before; the
    // server's replay/flush rebases to absolute time and must restore
    // this on exit (ISSUE 9 regression: a bare `set_sim_offset(0.0)`
    // used to leak into everything the thread traced afterwards)
    trace::set_sim_offset(123.5);

    let mut spec = ClusterSpec::uniform("pedestrian", 2, 3).expect("builtin task");
    for shard in &mut spec.shards {
        shard.cloudlet.model = shard.cloudlet.model.with_hidden(&[8]);
        shard.cloudlet.dataset.total_samples = 96;
    }
    let spec = spec.with_synthetic_churn(3.0 * T, 1, 9);
    let cluster = Cluster::new(
        spec.clone(),
        ClusterConfig {
            policy: Policy::Analytical,
            mode: Mode::Async,
            t_total: T,
            cycles: 3,
            seed: SEED,
            trace_spans: true,
            ..ClusterConfig::default()
        },
    );
    let dir = std::env::temp_dir().join(format!("mel-trace-live-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    // capacity 1 on a bursty 2-shard stream with a training-slow
    // consumer: the senders are guaranteed to block at least once
    let opts = mel::cluster::LiveOptions {
        checkpoint_every: 1,
        journal_dir: Some(dir.clone()),
        plane_capacity: 1,
        ..mel::cluster::LiveOptions::default()
    };
    let ps_cfg = mel::cluster::ParamServerConfig {
        lr: 0.05,
        eval_samples: 48,
        ..mel::cluster::ParamServerConfig::from_spec(&spec.global, SEED)
    };
    cluster.run_live(ps_cfg, &opts).expect("live run");

    assert_eq!(
        trace::sim_offset(),
        123.5,
        "the server flush leaked its sim-offset rebase onto the calling thread"
    );
    trace::set_sim_offset(0.0);

    let events = trace::drain();
    trace::set_enabled(false);
    for (cat, name) in
        [("plane", "backpressure_stall"), ("ps", "journal_append"), ("ps", "checkpoint")]
    {
        assert!(
            events.iter().any(|e| e.cat == cat && e.name == name),
            "live run is missing a {cat}/{name} event"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}
