//! Fixture tests for the `mel lint` analyzer (`rust/src/analysis/`):
//! every rule fires on a seeded violation at the exact `file:line`
//! anchor, rules never fire inside strings or comments, suppression
//! pragmas work (and malformed ones are unsuppressible findings), the
//! Cargo target cross-check catches orphans and ghosts, and — the
//! self-hosting payoff — the real tree scans clean.

use mel::analysis::project::{check_cargo_targets, check_env_registry, parse_cargo_targets};
use mel::analysis::rules::string_literals;
use mel::analysis::{lint_source, lint_tree, Finding, LintConfig, RuleId};
use std::path::Path;

fn cfg() -> LintConfig {
    LintConfig::default()
}

fn lines_for(findings: &[Finding], rule: RuleId) -> Vec<usize> {
    findings.iter().filter(|f| f.rule == rule).map(|f| f.line).collect()
}

// ---------------------------------------------------------------- D1

#[test]
fn d1_flags_partial_cmp_unwrap_and_expect_at_exact_lines() {
    let src = "fn f(v: &mut Vec<f64>) {\n\
               \x20   v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n\
               \x20   v.sort_by(|a, b| a.partial_cmp(b).expect(\"cmp\"));\n\
               }\n";
    let lint = lint_source("rust/src/alloc/x.rs", src, &cfg());
    assert_eq!(lines_for(&lint.findings, RuleId::D1), vec![2, 3]);
}

#[test]
fn d1_accepts_total_cmp_and_bare_partial_cmp() {
    let src = "fn f(v: &mut Vec<f64>) -> Option<std::cmp::Ordering> {\n\
               \x20   v.sort_by(|a, b| a.total_cmp(b));\n\
               \x20   v[0].partial_cmp(&v[1])\n\
               }\n";
    let lint = lint_source("rust/src/alloc/x.rs", src, &cfg());
    assert!(lines_for(&lint.findings, RuleId::D1).is_empty(), "{:?}", lint.findings);
}

// ---------------------------------------------------------------- D2

#[test]
fn d2_flags_for_loop_and_method_iteration_over_hashmap() {
    let src = "use std::collections::HashMap;\n\
               fn f(m: HashMap<u32, u32>) -> u32 {\n\
               \x20   let mut s = 0;\n\
               \x20   for (_k, v) in &m {\n\
               \x20       s += *v;\n\
               \x20   }\n\
               \x20   s\n\
               }\n\
               fn g(m: HashMap<String, u32>) -> usize {\n\
               \x20   m.keys().count()\n\
               }\n";
    let lint = lint_source("rust/src/cluster/x.rs", src, &cfg());
    assert_eq!(lines_for(&lint.findings, RuleId::D2), vec![4, 10]);
}

#[test]
fn d2_accepts_lookups_and_btreemap_iteration() {
    let src = "use std::collections::{BTreeMap, HashMap};\n\
               fn f(h: HashMap<u32, u32>, b: BTreeMap<u32, u32>) -> u32 {\n\
               \x20   let mut s = h.get(&3).copied().unwrap_or(0);\n\
               \x20   s += h.len() as u32;\n\
               \x20   for (_k, v) in &b {\n\
               \x20       s += *v;\n\
               \x20   }\n\
               \x20   s\n\
               }\n";
    let lint = lint_source("rust/src/cluster/x.rs", src, &cfg());
    assert!(lines_for(&lint.findings, RuleId::D2).is_empty(), "{:?}", lint.findings);
}

// ---------------------------------------------------------------- D3

#[test]
fn d3_confines_wall_clock_reads_to_sanctioned_modules() {
    let src = "pub fn f() -> f64 {\n\
               \x20   let t0 = std::time::Instant::now();\n\
               \x20   t0.elapsed().as_secs_f64()\n\
               }\n\
               pub fn g() -> std::time::SystemTime {\n\
               \x20   std::time::SystemTime::now()\n\
               }\n";
    let lint = lint_source("rust/src/sim/x.rs", src, &cfg());
    assert_eq!(lines_for(&lint.findings, RuleId::D3), vec![2, 6]);
    // the same source is sanctioned inside the tracing plane
    let lint = lint_source("rust/src/trace/x.rs", src, &cfg());
    assert!(lines_for(&lint.findings, RuleId::D3).is_empty());
}

// ---------------------------------------------------------------- D4

#[test]
fn d4_confines_thread_creation_to_sanctioned_modules() {
    let src = "pub fn f() {\n\
               \x20   std::thread::spawn(|| {}).join().ok();\n\
               }\n";
    let lint = lint_source("rust/src/alloc/x.rs", src, &cfg());
    assert_eq!(lines_for(&lint.findings, RuleId::D4), vec![2]);
    let lint = lint_source("rust/src/compute/pool.rs", src, &cfg());
    assert!(lines_for(&lint.findings, RuleId::D4).is_empty());
}

// ---------------------------------------------------------------- R1

#[test]
fn r1_flags_unwrap_expect_panic_in_library_code() {
    let src = "pub fn f(v: &[u32]) -> u32 {\n\
               \x20   let a = v.first().unwrap();\n\
               \x20   let b = v.last().expect(\"non-empty\");\n\
               \x20   if *a > *b { panic!(\"bad\"); }\n\
               \x20   a + b\n\
               }\n";
    let lint = lint_source("rust/src/models/x.rs", src, &cfg());
    assert_eq!(lines_for(&lint.findings, RuleId::R1), vec![2, 3, 4]);
}

#[test]
fn r1_accepts_fallible_variants() {
    let src = "pub fn f(v: &[u32]) -> u32 {\n\
               \x20   let a = v.first().copied().unwrap_or(0);\n\
               \x20   let b = v.last().copied().unwrap_or_else(|| 0);\n\
               \x20   let c: u32 = v.iter().sum::<u32>().checked_div(2).unwrap_or_default();\n\
               \x20   a + b + c\n\
               }\n";
    let lint = lint_source("rust/src/models/x.rs", src, &cfg());
    assert!(lines_for(&lint.findings, RuleId::R1).is_empty(), "{:?}", lint.findings);
}

#[test]
fn r1_exempts_main_rs_and_cfg_test_regions() {
    let src = "pub fn f(v: &[u32]) -> u32 {\n\
               \x20   *v.first().unwrap()\n\
               }\n";
    let lint = lint_source("rust/src/main.rs", src, &cfg());
    assert!(lint.findings.is_empty(), "{:?}", lint.findings);

    let src = "pub fn lib_fn() -> u32 { 1 }\n\
               #[cfg(test)]\n\
               mod tests {\n\
               \x20   #[test]\n\
               \x20   fn t() {\n\
               \x20       let v = vec![1u32];\n\
               \x20       assert_eq!(*v.first().unwrap(), 1);\n\
               \x20   }\n\
               }\n";
    let lint = lint_source("rust/src/models/x.rs", src, &cfg());
    assert!(lint.findings.is_empty(), "{:?}", lint.findings);
}

// ------------------------------------------------- strings & comments

#[test]
fn rules_never_fire_inside_strings_or_comments() {
    let src = "pub fn f() -> &'static str {\n\
               \x20   // a doc note may say partial_cmp(x).unwrap() freely\n\
               \x20   /* or panic!(\"...\") or std::thread::spawn */\n\
               \x20   \"partial_cmp(a).unwrap() panic! Instant::now thread::spawn\"\n\
               }\n";
    let lint = lint_source("rust/src/alloc/x.rs", src, &cfg());
    assert!(lint.findings.is_empty(), "{:?}", lint.findings);
}

// ---------------------------------------------------------- pragmas

#[test]
fn justified_pragmas_suppress_line_and_file_wide() {
    // full-line pragma covers the next code line
    let src = "pub fn f(v: &[u32]) -> u32 {\n\
               \x20   // mel-lint: allow(R1) — fixture invariant, always non-empty\n\
               \x20   *v.first().unwrap()\n\
               }\n";
    let lint = lint_source("rust/src/models/x.rs", src, &cfg());
    assert!(lint.findings.is_empty(), "{:?}", lint.findings);
    assert_eq!(lint.suppressed, 1);

    // trailing pragma covers its own line
    let src = "pub fn f(v: &[u32]) -> u32 {\n\
               \x20   *v.first().unwrap() // mel-lint: allow(R1) — fixture invariant\n\
               }\n";
    let lint = lint_source("rust/src/models/x.rs", src, &cfg());
    assert!(lint.findings.is_empty(), "{:?}", lint.findings);
    assert_eq!(lint.suppressed, 1);

    // allow-file exempts the whole file for the named rule only
    let src = "// mel-lint: allow-file(R1) — generated fixture\n\
               pub fn f(v: &mut Vec<f64>) -> f64 {\n\
               \x20   v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n\
               \x20   *v.first().unwrap()\n\
               }\n";
    let lint = lint_source("rust/src/models/x.rs", src, &cfg());
    assert_eq!(lines_for(&lint.findings, RuleId::D1), vec![3], "D1 must survive allow-file(R1)");
    assert_eq!(lint.suppressed, 2, "both unwraps suppressed by allow-file(R1)");
}

#[test]
fn pragma_without_justification_or_with_unknown_rule_is_a_finding() {
    let src = "pub fn f(v: &[u32]) -> u32 {\n\
               \x20   // mel-lint: allow(R1)\n\
               \x20   *v.first().unwrap()\n\
               }\n";
    let lint = lint_source("rust/src/models/x.rs", src, &cfg());
    // the pragma is rejected, so the unwrap still fires AND the pragma
    // itself is reported
    assert_eq!(lines_for(&lint.findings, RuleId::R1), vec![3]);
    assert_eq!(lines_for(&lint.findings, RuleId::Pragma), vec![2]);

    let src = "pub fn f() {\n\
               \x20   // mel-lint: allow(Z9) — no such rule\n\
               }\n";
    let lint = lint_source("rust/src/models/x.rs", src, &cfg());
    assert_eq!(lines_for(&lint.findings, RuleId::Pragma), vec![2]);
}

// ---------------------------------------------------------------- C1

#[test]
fn c1_cross_check_catches_orphans_and_ghosts() {
    let cargo = "[package]\n\
                 name = \"x\"\n\
                 \n\
                 [[test]]\n\
                 name = \"a\"\n\
                 path = \"rust/tests/a.rs\"\n\
                 \n\
                 [[test]]\n\
                 name = \"ghost\"\n\
                 path = \"rust/tests/ghost.rs\"\n\
                 \n\
                 [[bench]]\n\
                 name = \"b\"\n\
                 path = \"benches/b.rs\"\n";
    let targets = parse_cargo_targets(cargo);
    assert_eq!(targets.len(), 3);

    let tests = vec!["rust/tests/a.rs".to_string(), "rust/tests/orphan.rs".to_string()];
    let benches = vec!["benches/b.rs".to_string()];
    let findings = check_cargo_targets("Cargo.toml", cargo, &tests, &benches);
    assert_eq!(findings.len(), 2, "{findings:?}");
    // the orphan test file anchors at its own first line
    let orphan = findings.iter().find(|f| f.path == "rust/tests/orphan.rs").expect("orphan");
    assert_eq!((orphan.rule, orphan.line), (RuleId::C1, 1));
    // the ghost registration anchors at its Cargo.toml path line
    let ghost = findings.iter().find(|f| f.path == "Cargo.toml").expect("ghost");
    assert_eq!((ghost.rule, ghost.line), (RuleId::C1, 10));
    assert!(ghost.message.contains("ghost.rs"), "{}", ghost.message);
}

// ---------------------------------------------------------------- C2

#[test]
fn c2_flags_undocumented_mel_vars_only() {
    let src = "pub fn f() {\n\
               \x20   let _ = std::env::var(\"MEL_SECRET_KNOB\");\n\
               \x20   let _ = std::env::var(\"MEL_DOCUMENTED\");\n\
               \x20   let _ = std::env::var(\"OTHER_VAR\");\n\
               \x20   let _ = \"MEL_\";\n\
               }\n";
    let readme = "docs mention MEL_DOCUMENTED here";
    let files = vec![("rust/src/x.rs".to_string(), string_literals(src))];
    let findings = check_env_registry(&files, readme);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, RuleId::C2);
    assert_eq!(findings[0].line, 2);
    assert!(findings[0].message.contains("MEL_SECRET_KNOB"));
}

// ------------------------------------------------------- self-scan

#[test]
fn the_real_tree_is_self_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = lint_tree(root, &[], &LintConfig::default()).expect("tree scan");
    assert!(report.files_scanned > 50, "scanned only {} files", report.files_scanned);
    assert!(
        report.findings.is_empty(),
        "the tree must lint clean; found:\n{}",
        report.render_human()
    );
    assert_eq!(report.exit_code(), 0);
}

#[test]
fn tree_reports_are_deterministic() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let a = lint_tree(root, &[], &LintConfig::default()).expect("scan a");
    let b = lint_tree(root, &[], &LintConfig::default()).expect("scan b");
    assert_eq!(a.to_json().to_pretty(), b.to_json().to_pretty());
    let sorted = {
        let mut s = a.findings.clone();
        s.sort();
        s
    };
    assert_eq!(a.findings, sorted, "findings must come out sorted");
}

#[test]
fn explicit_path_mode_scans_only_the_given_files() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = lint_tree(
        root,
        &["rust/src/analysis/rules.rs".into()],
        &LintConfig::default(),
    )
    .expect("single-file scan");
    assert_eq!(report.files_scanned, 1);
    assert_eq!(report.exit_code(), 0, "{}", report.render_human());
    let err = lint_tree(root, &["rust/src/does_not_exist.rs".into()], &LintConfig::default());
    assert!(err.is_err());
}
