//! Integration: the PJRT runtime executes the real AOT artifacts and
//! the numerics match closed-form expectations (the same checks
//! python/tests validate against the jnp reference).
//!
//! Requires `make artifacts` (the Makefile's `test` target guarantees
//! the ordering).

use mel::coordinator::ParamSet;
use mel::runtime::{Engine, Manifest, Tensor};
use mel::require_artifacts;

fn engine() -> Engine {
    Engine::start("artifacts").expect("run `make artifacts` before `cargo test`")
}

/// Build (params, x, y, mask) for the pedestrian arch at bucket 64 with
/// all-zero parameters — closed-form loss: n·ln(C).
fn zero_param_inputs(n_real: usize) -> Vec<Tensor> {
    let layers = [648usize, 300, 2];
    let mut inputs = Vec::new();
    for w in layers.windows(2) {
        inputs.push(Tensor::zeros_f32(vec![w[0], w[1]]));
        inputs.push(Tensor::zeros_f32(vec![w[1]]));
    }
    let mut x = vec![0.1f32; 64 * 648];
    for (i, v) in x.iter_mut().enumerate() {
        *v = ((i % 7) as f32) / 7.0;
    }
    let y: Vec<i32> = (0..64).map(|i| (i % 2) as i32).collect();
    let mut mask = vec![1.0f32; n_real];
    mask.resize(64, 0.0);
    inputs.push(Tensor::f32(vec![64, 648], x));
    inputs.push(Tensor::i32(vec![64], y));
    inputs.push(Tensor::f32(vec![64], mask));
    inputs
}

#[test]
fn grad_step_zero_params_gives_ln2_loss() {
    require_artifacts!();
    let eng = engine();
    let h = eng.handle();
    let out = h
        .execute("pedestrian_grad_step_b64", zero_param_inputs(64))
        .expect("execute");
    assert_eq!(out.len(), 6); // 4 grads + loss_sum + weight_sum
    let loss = out[4].scalar() as f64;
    let weight = out[5].scalar() as f64;
    assert_eq!(weight, 64.0);
    // zero params → uniform logits → CE = ln 2 per sample
    assert!((loss - 64.0 * std::f64::consts::LN_2).abs() < 1e-3, "loss {loss}");
    // gradient shapes mirror parameters
    assert_eq!(out[0].dims, vec![648, 300]);
    assert_eq!(out[3].dims, vec![2]);
    // zero params → dead relu hidden layer → zero grads on layer 0, but
    // the output-layer bias grad must be finite and nonzero-summed
    assert!(out[3].as_f32().iter().all(|v| v.is_finite()));
}

#[test]
fn masking_is_neutral_through_pjrt() {
    require_artifacts!();
    let eng = engine();
    let h = eng.handle();
    let full = h.execute("pedestrian_grad_step_b64", zero_param_inputs(64)).unwrap();
    let masked = h.execute("pedestrian_grad_step_b64", zero_param_inputs(40)).unwrap();
    // weight_sum reflects the mask
    assert_eq!(masked[5].scalar(), 40.0);
    assert_eq!(full[5].scalar(), 64.0);
    // per-sample loss identical
    let l_full = full[4].scalar() / 64.0;
    let l_masked = masked[4].scalar() / 40.0;
    assert!((l_full - l_masked).abs() < 1e-5);
}

#[test]
fn eval_batch_counts_and_loss() {
    require_artifacts!();
    let eng = engine();
    let h = eng.handle();
    let mut inputs = zero_param_inputs(64);
    // keep only params + x,y,mask (eval takes the same signature)
    let out = h.execute("pedestrian_eval_batch_b64", std::mem::take(&mut inputs)).unwrap();
    assert_eq!(out.len(), 3);
    let (loss, correct, weight) = (out[0].scalar(), out[1].scalar(), out[2].scalar());
    assert_eq!(weight, 64.0);
    assert!((loss / 64.0 - std::f64::consts::LN_2 as f32).abs() < 1e-4);
    // uniform logits → argmax is class 0 → exactly the 32 even samples correct
    assert_eq!(correct, 32.0);
}

#[test]
fn sgd_descends_through_real_artifacts() {
    require_artifacts!();
    let eng = engine();
    let h = eng.handle();
    let layers = [648usize, 300, 2];
    let mut params = ParamSet::init(&layers, 3);

    // deterministic learnable batch: class = sign of first pixel block
    let n = 64usize;
    let mut x = vec![0.0f32; n * 648];
    let mut y = vec![0i32; n];
    for i in 0..n {
        let cls = (i % 2) as i32;
        y[i] = cls;
        for j in 0..648 {
            x[i * 648 + j] =
                if cls == 1 { 0.8 } else { 0.2 } + 0.1 * ((i * 648 + j) % 5) as f32 / 5.0;
        }
    }
    let xt = Tensor::f32(vec![n, 648], x);
    let yt = Tensor::i32(vec![n], y);
    let mt = Tensor::f32(vec![n], vec![1.0; n]);

    let mut losses = Vec::new();
    for _ in 0..12 {
        let mut inputs = params.tensors.clone();
        inputs.push(xt.clone());
        inputs.push(yt.clone());
        inputs.push(mt.clone());
        let out = h.execute("pedestrian_grad_step_b64", inputs).unwrap();
        let loss = out[4].scalar() / out[5].scalar();
        losses.push(loss);
        let grads: Vec<Tensor> = out[..4].to_vec();
        // lr 0.2: full-batch GD on this synthetic batch is stable here
        // (lr 1.0 overshoots into the uniform-predictor plateau).
        params.sgd_apply(&grads, 0.2, out[5].scalar());
    }
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.5),
        "loss should halve: {losses:?}"
    );
}

#[test]
fn chunked_accumulation_equals_single_batch() {
    require_artifacts!();
    // grad(sum over 64) == grad(sum over first 40) + grad(sum over last 24)
    let eng = engine();
    let h = eng.handle();
    let full = h.execute("pedestrian_grad_step_b64", zero_param_inputs(64)).unwrap();

    // chunk A: first 40 (mask 40), chunk B: rows shifted so the "real"
    // rows are the last 24 of the same data
    let mut a_in = zero_param_inputs(64);
    let mask_a: Vec<f32> = (0..64).map(|i| if i < 40 { 1.0 } else { 0.0 }).collect();
    a_in[6] = Tensor::f32(vec![64], mask_a);
    let a = h.execute("pedestrian_grad_step_b64", a_in).unwrap();

    let mut b_in = zero_param_inputs(64);
    let mask_b: Vec<f32> = (0..64).map(|i| if i >= 40 { 1.0 } else { 0.0 }).collect();
    b_in[6] = Tensor::f32(vec![64], mask_b);
    let b = h.execute("pedestrian_grad_step_b64", b_in).unwrap();

    for t in 0..4 {
        let f = full[t].as_f32();
        for (i, (&ga, &gb)) in a[t].as_f32().iter().zip(b[t].as_f32()).enumerate() {
            assert!(
                (f[i] - (ga + gb)).abs() < 1e-4 * (1.0 + f[i].abs()),
                "tensor {t} elem {i}: {} vs {}",
                f[i],
                ga + gb
            );
        }
    }
    assert!((full[4].scalar() - (a[4].scalar() + b[4].scalar())).abs() < 1e-3);
    assert_eq!(a[5].scalar() + b[5].scalar(), full[5].scalar());
}

#[test]
fn mnist_artifacts_execute() {
    require_artifacts!();
    let eng = engine();
    let h = eng.handle();
    let man = Manifest::load("artifacts").unwrap();
    let meta = man.find("mnist", "eval_batch", 128).expect("mnist artifact");
    let layers = [784usize, 300, 124, 60, 10];
    let mut inputs = Vec::new();
    for w in layers.windows(2) {
        inputs.push(Tensor::zeros_f32(vec![w[0], w[1]]));
        inputs.push(Tensor::zeros_f32(vec![w[1]]));
    }
    inputs.push(Tensor::zeros_f32(vec![128, 784]));
    inputs.push(Tensor::i32(vec![128], vec![3; 128]));
    inputs.push(Tensor::f32(vec![128], vec![1.0; 128]));
    let out = h.execute(&meta.name, inputs).unwrap();
    // zero params → uniform over 10 classes → loss = ln 10 per sample
    let loss = out[0].scalar() as f64 / 128.0;
    assert!((loss - 10f64.ln()).abs() < 1e-3, "loss {loss}");
}

#[test]
fn warm_compiles_ahead() {
    require_artifacts!();
    let eng = engine();
    let h = eng.handle();
    h.warm("pedestrian_eval_batch_b128").unwrap();
    assert!(h.warm("not_an_artifact").is_err());
}

#[test]
fn parallel_submissions_from_many_threads() {
    require_artifacts!();
    let eng = engine();
    let h = eng.handle();
    h.warm("pedestrian_grad_step_b64").unwrap();
    std::thread::scope(|s| {
        for _ in 0..6 {
            let h = h.clone();
            s.spawn(move || {
                for _ in 0..3 {
                    let out = h
                        .execute("pedestrian_grad_step_b64", zero_param_inputs(64))
                        .unwrap();
                    assert_eq!(out[5].scalar(), 64.0);
                }
            });
        }
    });
}
