//! Integration: the engine thread executes real compute and the
//! numerics match closed-form expectations (the same checks
//! python/tests validate against the jnp reference).
//!
//! The first half runs on **every** box through the hermetic native
//! backend (no artifacts, no `pjrt` feature). The second half exercises
//! the PJRT artifact path and is gated on `require_pjrt!` (needs
//! `make artifacts` + `--features pjrt`).

use mel::backend::{Call, Function};
use mel::coordinator::ParamSet;
use mel::require_pjrt;
use mel::runtime::{BackendKind, Engine, Manifest, Tensor};
// shared builder: zero params (closed-form loss n·ln C), y = i % C
use mel::testkit::zero_param_mlp_inputs as zero_param_inputs;

// ---------------------------------------------------------------------
// native backend through the engine thread — runs everywhere
// ---------------------------------------------------------------------

const NATIVE_LAYERS: [usize; 3] = [648, 32, 2];

fn native_engine() -> Engine {
    let eng = Engine::start_native();
    assert_eq!(eng.kind(), BackendKind::Native);
    eng
}

fn grad_call() -> Call {
    Call::new(Function::GradStep, "pedestrian", &NATIVE_LAYERS)
}

#[test]
fn native_grad_step_zero_params_gives_ln2_loss() {
    let eng = native_engine();
    let h = eng.handle();
    let out = h.call(&grad_call(), zero_param_inputs(&NATIVE_LAYERS, 64, 64)).expect("call");
    assert_eq!(out.len(), 6); // 4 grads + loss_sum + weight_sum
    let loss = out[4].scalar() as f64;
    let weight = out[5].scalar() as f64;
    assert_eq!(weight, 64.0);
    // zero params → uniform logits → CE = ln 2 per sample
    assert!((loss - 64.0 * std::f64::consts::LN_2).abs() < 1e-3, "loss {loss}");
    // gradient shapes mirror parameters
    assert_eq!(out[0].dims, vec![648, 32]);
    assert_eq!(out[3].dims, vec![2]);
    // zero params → dead relu hidden layer → zero grads on layer 0, but
    // the output-layer bias grad must be finite
    assert!(out[0].as_f32().iter().all(|&v| v == 0.0));
    assert!(out[3].as_f32().iter().all(|v| v.is_finite()));
}

#[test]
fn native_masking_is_neutral_through_engine() {
    let eng = native_engine();
    let h = eng.handle();
    let full = h.call(&grad_call(), zero_param_inputs(&NATIVE_LAYERS, 64, 64)).unwrap();
    let masked = h.call(&grad_call(), zero_param_inputs(&NATIVE_LAYERS, 64, 40)).unwrap();
    // weight_sum reflects the mask
    assert_eq!(masked[5].scalar(), 40.0);
    assert_eq!(full[5].scalar(), 64.0);
    // per-sample loss identical
    let l_full = full[4].scalar() / 64.0;
    let l_masked = masked[4].scalar() / 40.0;
    assert!((l_full - l_masked).abs() < 1e-5);
}

#[test]
fn native_eval_batch_counts_and_loss() {
    let eng = native_engine();
    let h = eng.handle();
    let call = Call::new(Function::EvalBatch, "pedestrian", &NATIVE_LAYERS);
    let out = h.call(&call, zero_param_inputs(&NATIVE_LAYERS, 64, 64)).unwrap();
    assert_eq!(out.len(), 3);
    let (loss, correct, weight) = (out[0].scalar(), out[1].scalar(), out[2].scalar());
    assert_eq!(weight, 64.0);
    assert!((loss / 64.0 - std::f64::consts::LN_2 as f32).abs() < 1e-4);
    // uniform logits → argmax is class 0 → exactly the 32 even samples correct
    assert_eq!(correct, 32.0);
}

/// The acceptance gate: real SGD through the engine, loss strictly
/// decreasing over a 10-update run — with no artifacts and no `pjrt`
/// feature anywhere in sight.
#[test]
fn native_sgd_descends_over_ten_updates() {
    let eng = native_engine();
    let h = eng.handle();
    let mut params = ParamSet::init(&NATIVE_LAYERS, 3);

    // deterministic learnable batch: class = feature level
    let n = 64usize;
    let mut x = vec![0.0f32; n * 648];
    let mut y = vec![0i32; n];
    for i in 0..n {
        let cls = (i % 2) as i32;
        y[i] = cls;
        for j in 0..648 {
            x[i * 648 + j] =
                if cls == 1 { 0.8 } else { 0.2 } + 0.1 * ((i * 648 + j) % 5) as f32 / 5.0;
        }
    }
    let xt = Tensor::f32(vec![n, 648], x);
    let yt = Tensor::i32(vec![n], y);
    let mt = Tensor::f32(vec![n], vec![1.0; n]);

    let mut losses = Vec::new();
    for _ in 0..10 {
        let mut inputs = params.tensors.clone();
        inputs.push(xt.clone());
        inputs.push(yt.clone());
        inputs.push(mt.clone());
        let out = h.call(&grad_call(), inputs).unwrap();
        losses.push(out[4].scalar() / out[5].scalar());
        let grads: Vec<Tensor> = out[..4].to_vec();
        // lr well below the curvature bound so full-batch GD descends
        // monotonically (large lr overshoots into oscillation)
        params.sgd_apply(&grads, 0.05, out[5].scalar());
    }
    assert!(
        losses.windows(2).all(|w| w[1] < w[0]),
        "loss must strictly decrease: {losses:?}"
    );
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.9),
        "loss should drop measurably: {losses:?}"
    );
}

#[test]
fn native_chunked_accumulation_equals_single_batch() {
    // grad(sum over 64) == grad(sum over first 40) + grad(sum over last 24)
    let eng = native_engine();
    let h = eng.handle();
    let full = h.call(&grad_call(), zero_param_inputs(&NATIVE_LAYERS, 64, 64)).unwrap();

    let mut a_in = zero_param_inputs(&NATIVE_LAYERS, 64, 64);
    let mask_a: Vec<f32> = (0..64).map(|i| if i < 40 { 1.0 } else { 0.0 }).collect();
    a_in[6] = Tensor::f32(vec![64], mask_a);
    let a = h.call(&grad_call(), a_in).unwrap();

    let mut b_in = zero_param_inputs(&NATIVE_LAYERS, 64, 64);
    let mask_b: Vec<f32> = (0..64).map(|i| if i >= 40 { 1.0 } else { 0.0 }).collect();
    b_in[6] = Tensor::f32(vec![64], mask_b);
    let b = h.call(&grad_call(), b_in).unwrap();

    for t in 0..4 {
        let f = full[t].as_f32();
        for (i, (&ga, &gb)) in a[t].as_f32().iter().zip(b[t].as_f32()).enumerate() {
            assert!(
                (f[i] - (ga + gb)).abs() < 1e-4 * (1.0 + f[i].abs()),
                "tensor {t} elem {i}: {} vs {}",
                f[i],
                ga + gb
            );
        }
    }
    assert!((full[4].scalar() - (a[4].scalar() + b[4].scalar())).abs() < 1e-3);
    assert_eq!(a[5].scalar() + b[5].scalar(), full[5].scalar());
}

#[test]
fn native_parallel_submissions_from_many_threads() {
    let eng = native_engine();
    let h = eng.handle();
    std::thread::scope(|s| {
        for _ in 0..6 {
            let h = h.clone();
            s.spawn(move || {
                for _ in 0..3 {
                    let out = h
                        .call(&grad_call(), zero_param_inputs(&NATIVE_LAYERS, 64, 64))
                        .unwrap();
                    assert_eq!(out[5].scalar(), 64.0);
                }
            });
        }
    });
}

#[test]
fn native_engine_serves_mnist_shapes_too() {
    let eng = native_engine();
    let h = eng.handle();
    let layers = [784usize, 16, 10];
    let call = Call::new(Function::EvalBatch, "mnist", &layers);
    let out = h.call(&call, zero_param_inputs(&layers, 32, 32)).unwrap();
    let loss = out[0].scalar() as f64 / 32.0;
    // zero params → uniform over 10 classes → loss = ln 10 per sample
    assert!((loss - 10f64.ln()).abs() < 1e-3, "loss {loss}");
}

#[test]
fn native_rejects_artifact_names_with_honest_error() {
    let eng = native_engine();
    let h = eng.handle();
    let err = h.execute("pedestrian_grad_step_b64", vec![]).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("native"), "{msg}");
    assert!(msg.contains("no AOT artifacts"), "{msg}");
    assert!(h.warm("pedestrian_grad_step_b64").is_err());
}

// ---------------------------------------------------------------------
// PJRT artifact path — needs `make artifacts` and --features pjrt
// ---------------------------------------------------------------------

fn pjrt_engine() -> Engine {
    // forced pjrt (not Auto): a corrupt manifest surfaces its parse
    // error here instead of a bare kind assertion after a silent
    // native fallback
    let eng = Engine::start_pjrt("artifacts").expect("run `make artifacts` before `cargo test`");
    assert_eq!(eng.kind(), BackendKind::Pjrt);
    eng
}

const PED_LAYERS: [usize; 3] = [648, 300, 2];

#[test]
fn pjrt_grad_step_zero_params_gives_ln2_loss() {
    require_pjrt!();
    let eng = pjrt_engine();
    let h = eng.handle();
    let out = h
        .execute("pedestrian_grad_step_b64", zero_param_inputs(&PED_LAYERS, 64, 64))
        .expect("execute");
    assert_eq!(out.len(), 6);
    let loss = out[4].scalar() as f64;
    assert_eq!(out[5].scalar(), 64.0);
    assert!((loss - 64.0 * std::f64::consts::LN_2).abs() < 1e-3, "loss {loss}");
    assert_eq!(out[0].dims, vec![648, 300]);
    assert_eq!(out[3].dims, vec![2]);
}

#[test]
fn pjrt_model_calls_resolve_to_bucketed_artifacts() {
    require_pjrt!();
    // the backend-agnostic Call path must route to the padded artifact
    let eng = pjrt_engine();
    let h = eng.handle();
    let call = Call::new(Function::GradStep, "pedestrian", &PED_LAYERS);
    let out = h.call(&call, zero_param_inputs(&PED_LAYERS, 64, 40)).unwrap();
    assert_eq!(out.len(), 6);
    assert_eq!(out[5].scalar(), 40.0);
    // a bucket the manifest does not have is a clean error
    let bad = h.call(&call, zero_param_inputs(&PED_LAYERS, 63, 63)).unwrap_err();
    assert!(bad.to_string().contains("bucket"), "{bad}");
}

#[test]
fn pjrt_masking_is_neutral() {
    require_pjrt!();
    let eng = pjrt_engine();
    let h = eng.handle();
    let full = h.execute("pedestrian_grad_step_b64", zero_param_inputs(&PED_LAYERS, 64, 64)).unwrap();
    let masked =
        h.execute("pedestrian_grad_step_b64", zero_param_inputs(&PED_LAYERS, 64, 40)).unwrap();
    assert_eq!(masked[5].scalar(), 40.0);
    assert_eq!(full[5].scalar(), 64.0);
    let l_full = full[4].scalar() / 64.0;
    let l_masked = masked[4].scalar() / 40.0;
    assert!((l_full - l_masked).abs() < 1e-5);
}

#[test]
fn pjrt_matches_native_gradients_on_the_same_inputs() {
    require_pjrt!();
    // the two backends implement one contract: same inputs, same grads
    let pjrt = pjrt_engine();
    let native = Engine::start_native();
    let call = Call::new(Function::GradStep, "pedestrian", &PED_LAYERS);
    let inputs = zero_param_inputs(&PED_LAYERS, 64, 48);
    let a = pjrt.handle().call(&call, inputs.clone()).unwrap();
    let b = native.handle().call(&call, inputs).unwrap();
    assert_eq!(a.len(), b.len());
    for (t, (ta, tb)) in a.iter().zip(&b).enumerate() {
        assert_eq!(ta.dims, tb.dims, "tensor {t}");
        for (i, (&va, &vb)) in ta.as_f32().iter().zip(tb.as_f32()).enumerate() {
            assert!(
                (va - vb).abs() < 1e-3 * (1.0 + va.abs()),
                "tensor {t} elem {i}: pjrt {va} vs native {vb}"
            );
        }
    }
}

#[test]
fn pjrt_eval_batch_counts_and_loss() {
    require_pjrt!();
    let eng = pjrt_engine();
    let h = eng.handle();
    let out = h
        .execute("pedestrian_eval_batch_b64", zero_param_inputs(&PED_LAYERS, 64, 64))
        .unwrap();
    assert_eq!(out.len(), 3);
    let (loss, correct, weight) = (out[0].scalar(), out[1].scalar(), out[2].scalar());
    assert_eq!(weight, 64.0);
    assert!((loss / 64.0 - std::f64::consts::LN_2 as f32).abs() < 1e-4);
    assert_eq!(correct, 32.0);
}

#[test]
fn pjrt_mnist_artifacts_execute() {
    require_pjrt!();
    let eng = pjrt_engine();
    let h = eng.handle();
    let man = Manifest::load("artifacts").unwrap();
    let meta = man.find("mnist", "eval_batch", 128).expect("mnist artifact");
    let layers = [784usize, 300, 124, 60, 10];
    let mut inputs = Vec::new();
    for w in layers.windows(2) {
        inputs.push(Tensor::zeros_f32(vec![w[0], w[1]]));
        inputs.push(Tensor::zeros_f32(vec![w[1]]));
    }
    inputs.push(Tensor::zeros_f32(vec![128, 784]));
    inputs.push(Tensor::i32(vec![128], vec![3; 128]));
    inputs.push(Tensor::f32(vec![128], vec![1.0; 128]));
    let out = h.execute(&meta.name, inputs).unwrap();
    let loss = out[0].scalar() as f64 / 128.0;
    assert!((loss - 10f64.ln()).abs() < 1e-3, "loss {loss}");
}

#[test]
fn pjrt_warm_compiles_ahead() {
    require_pjrt!();
    let eng = pjrt_engine();
    let h = eng.handle();
    h.warm("pedestrian_eval_batch_b128").unwrap();
    assert!(h.warm("not_an_artifact").is_err());
    h.warm_call(&Call::new(Function::GradStep, "pedestrian", &PED_LAYERS)).unwrap();
}
