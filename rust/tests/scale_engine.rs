//! Scale-engine integration: population-sampled scenarios must be a
//! *representation* change, not a semantics change. Expansion is
//! deterministic in the seed; the grouped allocator fast path keeps the
//! orchestrator's sync timeline bit-identical (ETA) or τ-identical with
//! conserved totals (UB-Analytical); and churn re-splits on a
//! population-backed pool conserve the dataset exactly, member by
//! member, no matter how many learners the groups expand to.

use mel::alloc::Policy;
use mel::cluster::{ChurnAwarePlanner, Cluster, ClusterConfig};
use mel::orchestrator::{CyclePlanner, Mode, Orchestrator, OrchestratorConfig};
use mel::scenario::{ChurnTrace, CloudletConfig, ClusterSpec, PopulationSpec, ShardSpec};

fn population(k: usize, groups: usize, seed: u64) -> PopulationSpec {
    let cloudlet = CloudletConfig::by_task("pedestrian", k).expect("builtin task");
    PopulationSpec::sample(&cloudlet, groups, seed)
}

fn sync_cfg(policy: Policy, grouped: bool, seed: u64) -> OrchestratorConfig {
    OrchestratorConfig {
        mode: Mode::Sync,
        policy,
        t_total: 30.0,
        cycles: 3,
        seed,
        grouped_alloc: grouped,
        ..OrchestratorConfig::default()
    }
}

#[test]
fn population_expansion_is_deterministic_in_the_seed() {
    for seed in [1u64, 7, 42] {
        let a = population(120, 6, seed).expand();
        let b = population(120, 6, seed).expand();
        assert_eq!(a.k(), 120);
        assert_eq!(a.dataset.total_samples, b.dataset.total_samples);
        for (la, lb) in a.learners.iter().zip(&b.learners) {
            // bit-for-bit: same sampled groups, same member coefficients
            let (ca, cb) = (la.coeffs(&a.model), lb.coeffs(&b.model));
            assert_eq!(ca.c2, cb.c2, "seed {seed}");
            assert_eq!(ca.c1, cb.c1, "seed {seed}");
            assert_eq!(ca.c0, cb.c0, "seed {seed}");
        }
        // a different seed draws different group placements — the
        // channel-side coefficients (c1/c0 depend on distance) move
        let other = population(120, 6, seed + 100).expand();
        let differs = a
            .learners
            .iter()
            .zip(&other.learners)
            .any(|(x, y)| x.coeffs(&a.model).c1 != y.coeffs(&other.model).c1);
        assert!(differs, "seed {seed}: different seeds must sample different groups");
    }
}

#[test]
fn grouped_orchestrator_matches_flat_on_expanded_populations() {
    // The sublinear per-group solve is an equivalence transform of the
    // legacy per-learner path: ETA timelines are bit-identical, and
    // UB-Analytical agrees on τ with exact conservation. Covers the
    // 1-group (fully homogeneous) collapse and a multi-group pool.
    for (groups, seed) in [(1usize, 3u64), (4, 9)] {
        let pop = population(100, groups, seed);
        let flat_eta =
            Orchestrator::new(pop.expand(), sync_cfg(Policy::Eta, false, seed)).run().unwrap();
        let grp_eta =
            Orchestrator::new(pop.expand(), sync_cfg(Policy::Eta, true, seed)).run().unwrap();
        assert_eq!(flat_eta.rounds.len(), grp_eta.rounds.len());
        for (a, b) in flat_eta.rounds.iter().zip(&grp_eta.rounds) {
            assert_eq!(a.alloc.tau, b.alloc.tau, "{groups} group(s)");
            assert_eq!(a.alloc.batches, b.alloc.batches, "{groups} group(s)");
            // bit-for-bit: identical batches drive identical timelines
            assert_eq!(a.makespan, b.makespan, "{groups} group(s)");
            assert_eq!(a.completion, b.completion, "{groups} group(s)");
        }

        let d = pop.dataset.total_samples;
        let flat_ana = Orchestrator::new(pop.expand(), sync_cfg(Policy::Analytical, false, seed))
            .run()
            .unwrap();
        let grp_ana = Orchestrator::new(pop.expand(), sync_cfg(Policy::Analytical, true, seed))
            .run()
            .unwrap();
        for (a, b) in flat_ana.rounds.iter().zip(&grp_ana.rounds) {
            assert_eq!(a.alloc.tau, b.alloc.tau, "{groups} group(s)");
            assert_eq!(b.alloc.batches.iter().sum::<usize>(), d, "{groups} group(s)");
            assert!(b.deadline_misses.is_empty(), "{groups} group(s)");
        }
    }
}

#[test]
fn grouped_churn_resplits_conserve_the_dataset() {
    // Depart/rejoin storms on a population-backed pool: every re-split
    // through the grouped path hands out exactly d samples across the
    // active members, matching the flat planner's conservation law.
    let pop = population(96, 6, 11);
    let problem = pop.expand().problem(30.0);
    let d = pop.dataset.total_samples;
    let k = problem.k();
    for policy in [Policy::Eta, Policy::Analytical] {
        let mut grouped = ChurnAwarePlanner::new(policy, vec![true; k]).with_grouped(true);
        let mut flat = ChurnAwarePlanner::new(policy, vec![true; k]);
        grouped.plan_round(&problem, 0.0).expect("feasible");
        flat.plan_round(&problem, 0.0).expect("feasible");
        assert_eq!(grouped.planned_batches().iter().sum::<usize>(), d);
        // a storm: drop a prefix one by one, then bring everyone back
        let mut now = 1.0;
        for i in 0..8 {
            grouped.on_membership(i, false, &problem, now);
            flat.on_membership(i, false, &problem, now);
            now += 1.0;
            assert_eq!(
                grouped.planned_batches().iter().sum::<usize>(),
                d,
                "{policy:?}: conservation lost after {} departures",
                i + 1
            );
            for gone in 0..=i {
                assert_eq!(grouped.planned_batches()[gone], 0, "{policy:?}");
            }
            if policy == Policy::Eta {
                // grouped and flat ETA re-splits stay bit-identical
                assert_eq!(grouped.planned_batches(), flat.planned_batches());
            }
        }
        for i in 0..8 {
            grouped.on_membership(i, true, &problem, now);
            now += 1.0;
        }
        assert_eq!(grouped.planned_batches().iter().sum::<usize>(), d, "{policy:?}");
        assert_eq!(grouped.resplits(), 17, "{policy:?}: one initial + one per event");
    }
}

#[test]
fn population_shard_runs_through_the_cluster_under_churn() {
    // End to end: a ShardSpec with a population (no per-learner
    // cloudlet sampling) runs the full cluster path — grouped
    // allocation is automatic — under synthetic churn, deterministically.
    let pop = population(64, 4, 5);
    let k = pop.k();
    let spec = || {
        let s = ClusterSpec {
            shards: vec![ShardSpec {
                cloudlet: CloudletConfig::by_task("pedestrian", k).unwrap(),
                seed_offset: 0,
                churn: ChurnTrace::default(),
                population: Some(pop.clone()),
            }],
            global: Default::default(),
        };
        s.with_synthetic_churn(120.0, 3, 5)
    };
    let cfg = ClusterConfig {
        policy: Policy::Analytical,
        mode: Mode::Async,
        t_total: 30.0,
        cycles: 4,
        seed: 5,
        ..ClusterConfig::default()
    };
    let first = Cluster::new(spec(), cfg.clone()).run().unwrap();
    assert_eq!(first.shards.len(), 1);
    assert!(first.updates_applied > 0);
    let sr = &first.shards[0];
    assert!(sr.joins + sr.departs > 0, "synthetic churn produced no events");
    assert!(sr.resplits >= 2, "churn must force grouped re-splits");
    // seeded end to end, population path included
    let second = Cluster::new(spec(), cfg).run().unwrap();
    assert_eq!(first.updates_applied, second.updates_applied);
    assert_eq!(first.updates.len(), second.updates.len());
    for ((sa, a), (sb, b)) in first.updates.iter().zip(&second.updates) {
        assert_eq!(sa, sb);
        assert_eq!(a.learner, b.learner);
        assert_eq!(a.uploaded_at, b.uploaded_at);
        assert_eq!(a.batch, b.batch);
    }
}
