//! Native training-step bench: fwd+bwd+SGD latency of the hermetic
//! pure-Rust executor over a (batch × hidden-width) sweep, the
//! **quantized precision sweep** (ISSUE 6: `precision_bits ∈ {8,16,32}`
//! — int8 GEMMs vs grid fake-quant vs f32 on the same shape), the
//! **fused-vs-unfused step comparison** (one `fused_step` call against
//! `grad_step` + accumulate + `sgd_apply`), the engine-thread dispatch
//! overhead on top of a direct backend call, and the compute-pool
//! **thread sweep** (ISSUE 5): the same wide-layer grad step at 1/2/4/8
//! pool threads, with the speedup over the serial path reported
//! informatively (multi-core hosts should beat serial; the sweep never
//! fails the bench — CI gates on the stored baseline per bench name,
//! and thread-count entries are compared only against their own
//! history).
//! Prints the effective FLOP rate next to the paper's modeled learner
//! rates so the simulated compute profiles stay honest. Emits
//! `results/BENCH_train_step.json` via `benchkit::Suite`.
//!
//! Runs everywhere — no artifacts, no `pjrt` feature.
//!
//! ```bash
//! cargo bench --bench train_step
//! ```

use mel::backend::{Backend, Call, Function, NativeBackend};
use mel::benchkit::{group, Bencher, Suite};
use mel::coordinator::ParamSet;
use mel::runtime::{Engine, Tensor};

/// Inputs for a pedestrian-shaped (648 → hidden → 2) grad step.
fn inputs(hidden: usize, batch: usize) -> (Call, Vec<Tensor>) {
    let layers = [648usize, hidden, 2];
    let call = Call::new(Function::GradStep, "pedestrian", &layers);
    let params = ParamSet::init(&layers, 1);
    let mut v = params.tensors;
    v.push(Tensor::f32(
        vec![batch, 648],
        (0..batch * 648).map(|i| (i % 255) as f32 / 255.0).collect(),
    ));
    v.push(Tensor::i32(vec![batch], (0..batch).map(|i| (i % 2) as i32).collect()));
    v.push(Tensor::f32(vec![batch], vec![1.0; batch]));
    (call, v)
}

/// fwd+bwd flops of one step under the 4·MAC convention.
fn step_flops(hidden: usize, batch: usize) -> f64 {
    (4 * (648 * hidden + hidden * 2) * batch) as f64
}

fn main() {
    let b = Bencher::default();
    let mut suite = Suite::new("train_step");
    let mut be = NativeBackend::new();

    group("native grad_step (fwd+bwd) by batch x hidden width");
    for &hidden in &[32usize, 128, 300] {
        for &batch in &[32usize, 128] {
            let (call, ins) = inputs(hidden, batch);
            let r = suite.run(&b, &format!("grad_step h={hidden} b={batch}"), || {
                be.execute(&call, ins.clone()).unwrap()[5].scalar()
            });
            println!(
                "    → {:.2} GFLOP/s effective vs paper learner rates 0.175 (rpi) / \
                 1.2 (laptop) GFLOP/s",
                step_flops(hidden, batch) / r.mean / 1e9
            );
        }
    }

    group("quantized (P_m-bit) grad_step: precision_bits x batch sweep");
    {
        let mut mean32 = 0.0f64;
        let mut mean8 = 0.0f64;
        for &bits in &[32u32, 16, 8] {
            for &batch in &[64usize, 256] {
                let (call, ins) = inputs(300, batch);
                let call = call.with_precision(bits);
                let r = suite.run(&b, &format!("grad_step bits={bits} h=300 b={batch}"), || {
                    be.execute(&call, ins.clone()).unwrap()[5].scalar()
                });
                if batch == 256 {
                    if bits == 32 {
                        mean32 = r.mean;
                    } else if bits == 8 {
                        mean8 = r.mean;
                    }
                }
            }
        }
        if mean32 > 0.0 && mean8 > 0.0 {
            println!(
                "    → int8 (P_m=8) step is {:.2}x the f32 rate at h=300 b=256",
                mean32 / mean8
            );
        }
    }

    group("full SGD step (grad + apply) at paper shape h=300 b=64");
    {
        let (call, ins) = inputs(300, 64);
        let mut params = ParamSet::init(&[648, 300, 2], 2);
        suite.run(&b, "grad_step + sgd_apply h=300 b=64", || {
            let mut v = params.tensors.clone();
            v.extend(ins[ins.len() - 3..].iter().cloned());
            let out = be.execute(&call, v).unwrap();
            let grads: Vec<Tensor> = out[..4].to_vec();
            params.sgd_apply(&grads, 0.05, out[5].scalar());
            params.tensors[0].as_f32()[0]
        });
    }

    group("fused fwd+bwd+SGD vs unfused grad_step + sgd_apply, h=300 b=256");
    {
        let (call, ins) = inputs(300, 256);
        // each closure replays exactly one local_training iteration of
        // its path (params clone included), so the ratio is the real
        // per-iteration win
        let mut params = ParamSet::init(&[648, 300, 2], 2);
        let unfused = suite.run(&b, "unfused step h=300 b=256", || {
            let mut v = params.tensors.clone();
            v.extend(ins[ins.len() - 3..].iter().cloned());
            let out = be.execute(&call, v).unwrap();
            let np = params.tensors.len();
            let mut acc = params.zeros_like();
            for (a, g) in acc.iter_mut().zip(&out[..np]) {
                a.axpy(1.0, g);
            }
            params.sgd_apply(&acc, 0.05, out[np + 1].scalar());
            params.tensors[0].as_f32()[0]
        });
        let fcall = Call::new(Function::FusedStep, "pedestrian", &[648, 300, 2]);
        let mut params = ParamSet::init(&[648, 300, 2], 2);
        let fused = suite.run(&b, "fused step h=300 b=256", || {
            let mut v = params.tensors.clone();
            v.extend(ins[ins.len() - 3..].iter().cloned());
            v.push(Tensor::scalar_f32(0.05));
            let out = be.execute(&fcall, v).unwrap();
            for (p, np) in params.tensors.iter_mut().zip(out) {
                *p = np;
            }
            params.tensors[0].as_f32()[0]
        });
        println!(
            "    → fused step at {:.2}x the unfused rate",
            unfused.mean / fused.mean
        );
    }

    group("compute-pool thread sweep: wide-layer grad_step h=512 b=256");
    {
        let (call, ins) = inputs(512, 256);
        let host = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let mut serial_mean = 0.0f64;
        let mut best_speedup = 1.0f64;
        for &threads in &[1usize, 2, 4, 8] {
            let mut be = NativeBackend::with_threads(threads);
            let r = suite.run(&b, &format!("grad_step h=512 b=256 threads={threads}"), || {
                be.execute(&call, ins.clone()).unwrap()[5].scalar()
            });
            if threads == 1 {
                serial_mean = r.mean;
            } else if serial_mean > 0.0 {
                let speedup = serial_mean / r.mean;
                best_speedup = best_speedup.max(speedup);
                println!(
                    "    → {speedup:.2}x vs threads=1 ({:.2} GFLOP/s effective)",
                    step_flops(512, 256) / r.mean / 1e9
                );
            }
        }
        // informative gate, never flaky-fatal: a multi-core host should
        // beat the serial path on this shape
        if host > 1 && best_speedup <= 1.05 {
            println!(
                "    WARN: pooled matmul did not beat serial ({best_speedup:.2}x on a \
                 {host}-core host) — check MEL_THREADS / load"
            );
        } else {
            println!(
                "    OK: best pooled speedup {best_speedup:.2}x on a {host}-core host"
            );
        }
    }

    group("engine dispatch overhead (mpsc round trip vs direct call)");
    {
        let (call, ins) = inputs(32, 32);
        let direct = suite.run(&b, "direct backend call h=32 b=32", || {
            be.execute(&call, ins.clone()).unwrap()[5].scalar()
        });
        let engine = Engine::start_native();
        let h = engine.handle();
        let via_engine = suite.run(&b, "through engine thread h=32 b=32", || {
            h.call(&call, ins.clone()).unwrap()[5].scalar()
        });
        println!(
            "    → engine thread adds {:.1} µs per call over the direct {:.1} µs",
            (via_engine.mean - direct.mean).max(0.0) * 1e6,
            direct.mean * 1e6
        );
    }

    suite.write_and_report();
}
