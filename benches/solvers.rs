//! Solver scaling bench (our S1 experiment): how each allocation
//! solver's latency grows with K — the empirical backing for the
//! paper's "solving a K-th order polynomial may be computationally
//! expensive for large K" motivation of UB-SAI.
//!
//! Fits a power law time ≈ c·K^p per solver and reports p.
//!
//! ```bash
//! cargo bench --bench solvers
//! ```

use mel::alloc::analytical::{AnalyticalAllocator, RootMethod};
use mel::alloc::exact::ExactAllocator;
use mel::alloc::heuristic::UbSaiAllocator;
use mel::alloc::numerical::{Method, NumericalAllocator};
use mel::alloc::TaskAllocator;
use mel::benchkit::{group, Bencher, Suite};
use mel::scenario::{CloudletConfig, Scenario};
use mel::util::stats::power_fit;

fn main() {
    let b = Bencher::default();
    let seed = 42;

    let solvers: Vec<(&str, Box<dyn TaskAllocator>)> = vec![
        ("eq.21 polynomial (Durand-Kerner)",
            Box::new(AnalyticalAllocator::with_method(RootMethod::Polynomial))),
        ("rational form (Newton)",
            Box::new(AnalyticalAllocator::with_method(RootMethod::Newton))),
        ("UB-SAI (eq.32 + suggest-and-improve)", Box::new(UbSaiAllocator)),
        ("numerical bisection", Box::new(NumericalAllocator::with_method(Method::Bisection))),
        ("numerical alternating",
            Box::new(NumericalAllocator::with_method(Method::AlternatingFixedPoint))),
        ("exact integer (binary search)", Box::new(ExactAllocator)),
    ];

    let ks = [5usize, 10, 20, 40, 80];
    let mut times: Vec<Vec<f64>> = vec![Vec::new(); solvers.len()];
    let mut suite = Suite::new("solvers");

    for &k in &ks {
        group(&format!("K = {k} (pedestrian, T = 30 s)"));
        // scale d with K so the problem stays feasible and comparable
        let mut cfg = CloudletConfig::pedestrian(k);
        cfg.dataset.total_samples = 180 * k;
        let scenario = Scenario::random_cloudlet(&cfg, seed);
        let problem = scenario.problem(30.0);
        for (i, (name, solver)) in solvers.iter().enumerate() {
            // polynomial path overflows beyond K ≈ 100; skip gracefully
            if *name == "eq.21 polynomial (Durand-Kerner)" && k > 80 {
                continue;
            }
            let r = suite.run(&b, &format!("{name} K={k}"), || {
                solver.allocate(&problem).unwrap().tau
            });
            times[i].push(r.median);
        }
    }

    group("scaling exponents (time ~ c*K^p)");
    let kf: Vec<f64> = ks.iter().map(|&k| k as f64).collect();
    for (i, (name, _)) in solvers.iter().enumerate() {
        if times[i].len() == ks.len() {
            let (_, p, r2) = power_fit(&kf, &times[i]);
            println!("{name:<42} p = {p:.2}  (r² = {r2:.3})");
        }
    }
    println!(
        "\nexpected: polynomial ≳ 2 (O(K²) expansion + O(K²)/iter roots), \
         Newton/SAI/bisection ≈ 1 (O(K) per evaluation)"
    );

    // consistency: all solvers must produce the same τ at every K
    group("cross-solver agreement");
    for &k in &ks {
        let mut cfg = CloudletConfig::pedestrian(k);
        cfg.dataset.total_samples = 180 * k;
        let scenario = Scenario::random_cloudlet(&cfg, seed);
        let problem = scenario.problem(30.0);
        let taus: Vec<u64> =
            solvers.iter().map(|(_, s)| s.allocate(&problem).unwrap().tau).collect();
        assert!(taus.windows(2).all(|w| w[0] == w[1]), "K={k}: {taus:?}");
        println!("K={k}: all 6 solvers agree at tau = {}", taus[0]);
    }
    suite.write_and_report();
}
