//! End-to-end global-cycle bench: the orchestrator's full per-cycle
//! path (allocate → draw batches → real PJRT local training → aggregate
//! → evaluate) on a small cloudlet, plus the pure-coordination overhead
//! with compute excluded — showing L3 is not the bottleneck (the
//! paper's contribution lives in the allocation, which costs µs).
//!
//! Requires `make artifacts`.
//!
//! ```bash
//! cargo bench --bench e2e_cycle
//! ```

use mel::alloc::Policy;
use mel::benchkit::{group, Bencher, Suite};
use mel::coordinator::{Orchestrator, TrainConfig};
use mel::dataset::SyntheticDataset;
use mel::scenario::{CloudletConfig, Scenario};
use mel::sim::CycleSim;
use mel::util::rng::Pcg64;

fn main() {
    let b = Bencher::quick();
    let seed = 42;
    let mut suite = Suite::new("e2e_cycle");

    group("coordination-only path (no PJRT compute)");
    let scenario = Scenario::random_cloudlet(&CloudletConfig::pedestrian(20), seed);
    let problem = scenario.problem(30.0);
    let alloc = Policy::Analytical.allocator().allocate(&problem).unwrap();
    // 1. the allocation decision
    let solver = Policy::Analytical.allocator();
    suite.run(&b, "allocate (UB-Analytical, K=20)", || solver.allocate(&problem).unwrap().tau);
    // 2. batch draw over the full 9,000-sample dataset
    let ds = SyntheticDataset::full(&scenario.dataset, 1);
    let mut rng = Pcg64::seeded(2);
    suite.run(&b, "draw_batches (9,000 samples → 20 learners)", || {
        ds.draw_batches(&alloc.batches, &mut rng).len()
    });
    // 3. the discrete-event timeline
    let sim = CycleSim::from_problem(&problem);
    suite.run(&b, "cycle timeline simulation (no trace)", || sim.run_cycle(&alloc, false).makespan);
    // 4. aggregation at pedestrian scale (4 tensors, ~195k params × 20)
    let params = mel::coordinator::ParamSet::init(&[648, 300, 2], 1);
    let sets: Vec<(f64, mel::coordinator::ParamSet)> =
        (0..20).map(|i| ((i + 1) as f64, params.clone())).collect();
    suite.run(&b, "aggregate eq.(5) (20 learners x 195k params)", || {
        mel::coordinator::ParamSet::weighted_average(&sets).num_scalars()
    });

    // 5. the event-driven orchestration core: one barrier cycle through
    // the event queue (cached allocation) and a full async horizon
    group("event-driven orchestration core");
    {
        use mel::orchestrator::{Mode, Orchestrator as Core, OrchestratorConfig};
        let mut core = Core::new(
            Scenario::random_cloudlet(&CloudletConfig::pedestrian(20), seed),
            OrchestratorConfig { cycles: 1, ..OrchestratorConfig::default() },
        );
        let mut c = 0usize;
        suite.run(&b, "event core: sync cycle (K=20, cached alloc)", || {
            c += 1;
            core.step_cycle(c).unwrap().makespan
        });
        // scenario + core hoisted out of the closure so the number
        // tracks the event loop, not cloudlet generation
        let mut async_core = Core::new(
            Scenario::random_cloudlet(&CloudletConfig::pedestrian(10), seed),
            OrchestratorConfig {
                mode: Mode::Async,
                policy: Policy::Eta,
                cycles: 8,
                ..OrchestratorConfig::default()
            },
        );
        suite.run(&b, "event core: async horizon (K=10, 8 leases/learner)", || {
            async_core.run().unwrap().updates_applied
        });
    }

    // real compute runs on every box now: PJRT over the artifacts when
    // available, the hermetic native executor otherwise
    group("full cycle with real compute (K=3, d=384, T=2s)");
    let mut cloudlet = CloudletConfig::pedestrian(3);
    if !mel::runtime::pjrt_available() {
        // shrink the executed graph on the native path (timing
        // coefficients stay at the published values)
        cloudlet.model = cloudlet.model.with_hidden(&[32]);
    }
    let mut s = Scenario::random_cloudlet(&cloudlet, seed);
    s.dataset.total_samples = 384;
    let cfg = TrainConfig {
        policy: Policy::Analytical,
        t_total: 2.0,
        cycles: 1,
        lr: 0.05,
        seed,
        eval_samples: 128,
        dispatch_threads: 3,
        ..TrainConfig::default()
    };
    let mut orch = Orchestrator::new(s, cfg).expect("engine init");
    println!("(execution backend: {})", orch.backend_kind().label());
    // warm: the first cycle compiles artifacts / touches caches
    orch.run_cycle(0).unwrap();
    let t0 = std::time::Instant::now();
    let n = 5;
    for c in 0..n {
        orch.run_cycle(c + 1).unwrap();
    }
    let per = t0.elapsed().as_secs_f64() / n as f64;
    let tau = orch.metrics.gauge_value("tau").unwrap_or(0.0);
    println!(
        "full global cycle (τ={tau}, 3 learners, real grad-steps): {:.2} s wall — \
         simulated cycle budget T = 2 s",
        per
    );
    println!(
        "coordination overhead (allocate+draw+timeline+aggregate) is ~1e-3 of the \
         compute path → L3 is not the bottleneck"
    );
    suite.write_and_report();
}
