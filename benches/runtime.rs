//! PJRT runtime bench: latency/throughput of the compiled grad-step and
//! eval artifacts — the L1/L2 hot path as the coordinator sees it.
//! Reports per-sample throughput and the effective FLOP rate vs the
//! paper's modeled learner rates.
//!
//! Requires `make artifacts`.
//!
//! ```bash
//! cargo bench --bench runtime
//! ```

use mel::benchkit::{group, Bencher, Suite};
use mel::runtime::{Engine, Tensor};

fn ped_inputs(bucket: usize) -> Vec<Tensor> {
    let layers = [648usize, 300, 2];
    let mut inputs = Vec::new();
    for w in layers.windows(2) {
        inputs.push(Tensor::f32(
            vec![w[0], w[1]],
            (0..w[0] * w[1]).map(|i| ((i % 13) as f32 - 6.0) * 0.01).collect(),
        ));
        inputs.push(Tensor::zeros_f32(vec![w[1]]));
    }
    inputs.push(Tensor::f32(
        vec![bucket, 648],
        (0..bucket * 648).map(|i| (i % 255) as f32 / 255.0).collect(),
    ));
    inputs.push(Tensor::i32(vec![bucket], (0..bucket).map(|i| (i % 2) as i32).collect()));
    inputs.push(Tensor::f32(vec![bucket], vec![1.0; bucket]));
    inputs
}

fn main() {
    if !mel::runtime::pjrt_available() {
        println!(
            "skipping runtime bench: requires `make artifacts` and --features pjrt \
             (the hermetic path is covered by `cargo bench --bench train_step`)"
        );
        return;
    }
    let mut suite = Suite::new("runtime");
    let engine = Engine::start("artifacts").expect("run `make artifacts` first");
    let h = engine.handle();
    let b = Bencher::default();

    group("grad_step latency by bucket (pedestrian, C_m = 781,208 flop/sample)");
    for bucket in [64usize, 128, 256] {
        let name = format!("pedestrian_grad_step_b{bucket}");
        h.warm(&name).unwrap();
        let inputs = ped_inputs(bucket);
        let r = suite.run(&b, &format!("{name}"), || {
            h.execute(&name, inputs.clone()).unwrap()[5].scalar()
        });
        let flops = bucket as f64 * 781_208.0;
        println!(
            "    → {:.1} Msamples-flops/s effective: {:.2} GFLOP/s vs paper learner \
             rates 0.175 (rpi) / 1.2 (laptop) GFLOP/s",
            bucket as f64 / r.mean / 1e6,
            flops / r.mean / 1e9
        );
    }

    group("eval_batch latency");
    for bucket in [64usize, 256] {
        let name = format!("pedestrian_eval_batch_b{bucket}");
        h.warm(&name).unwrap();
        let inputs = ped_inputs(bucket);
        suite.run(&b, &name, || h.execute(&name, inputs.clone()).unwrap()[0].scalar());
    }

    group("engine dispatch overhead (tensor codec + channel round trip)");
    // smallest artifact, smallest payload → overhead-dominated
    let name = "pedestrian_eval_batch_b64";
    let inputs = ped_inputs(64);
    let r = suite.run(&b, "eval_b64 total", || h.execute(name, inputs.clone()).unwrap().len());
    println!(
        "    → dispatch+codec budget is bounded by this end-to-end time ({:.2} ms); \
         the engine thread adds one mpsc round trip per call",
        r.mean * 1e3
    );

    group("concurrent submission scaling (4 threads, grad_step b128)");
    h.warm("pedestrian_grad_step_b128").unwrap();
    let r1 = b.bench("1 thread", || {
        h.execute("pedestrian_grad_step_b128", ped_inputs(128)).unwrap();
    });
    let t0 = std::time::Instant::now();
    let reps = 12;
    std::thread::scope(|s| {
        for _ in 0..4 {
            let h = h.clone();
            s.spawn(move || {
                for _ in 0..reps / 4 {
                    h.execute("pedestrian_grad_step_b128", ped_inputs(128)).unwrap();
                }
            });
        }
    });
    let t4 = t0.elapsed().as_secs_f64() / reps as f64;
    println!("1-thread {:.2} ms/exec vs 4-thread {:.2} ms/exec (engine serializes submissions; XLA parallelizes internally)",
        r1.mean * 1e3, t4 * 1e3);
    suite.push(r1);
    suite.write_and_report();
}
