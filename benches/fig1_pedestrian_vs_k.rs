//! Bench/repro target for **Fig. 1**: pedestrian dataset, τ vs number
//! of edge nodes K for T = 30 and 60 s, all four schemes.
//!
//! Prints the figure's series (the reproduction) and then times the
//! underlying solve for each K (the bench).
//!
//! ```bash
//! cargo bench --bench fig1_pedestrian_vs_k
//! ```

use mel::alloc::Policy;
use mel::benchkit::{group, Bencher, Suite};
use mel::experiments;
use mel::scenario::{CloudletConfig, Scenario};

fn main() {
    let seed = 42;
    group("Fig. 1 — pedestrian: tau vs K (T = 30, 60 s)");
    let data = experiments::fig1(seed);
    print!("{}", data.table().render());

    // paper-vs-ours anchors
    let ana30 = data.series_by_prefix("UB-Analytical T=30").unwrap();
    let eta30 = data.series_by_prefix("ETA T=30").unwrap();
    println!(
        "anchor K=50 T=30s: ETA {} vs adaptive {} (paper: 36 vs 162) → gain {:.1}x (paper 4.5x)\n",
        eta30[9],
        ana30[9],
        ana30[9] as f64 / eta30[9] as f64
    );

    group("solve-time per (K, policy) point");
    let b = Bencher::default();
    let mut suite = Suite::new("fig1_pedestrian_vs_k");
    for &k in &[5usize, 20, 50] {
        let scenario = Scenario::random_cloudlet(&CloudletConfig::pedestrian(k), seed);
        let problem = scenario.problem(30.0);
        for policy in Policy::all() {
            let alloc = policy.allocator();
            suite.run(&b, &format!("fig1 K={k} {}", policy.label()), || {
                alloc.allocate(&problem).unwrap().tau
            });
        }
    }
    suite.write_and_report();
}
