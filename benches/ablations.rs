//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. **SAI start point** — eq.(32) equal-batch start vs the relaxed
//!    τ* start vs a cold start (τ=1): steps to converge.
//! 2. **Rounding strategy** — proportional largest-remainder fill vs
//!    naive floor-and-dump: feasibility and τ achieved.
//! 3. **Fading** — static Table-I channels vs per-cycle Rayleigh+shadow
//!    redraw: τ distribution and ETA/adaptive gap.
//! 4. **Bucket set** — runtime chunk plans {64,128,256} vs {256} only:
//!    padding waste per learner batch.
//!
//! ```bash
//! cargo bench --bench ablations
//! ```

use mel::alloc::heuristic::UbSaiAllocator;
use mel::alloc::sai;
use mel::alloc::Policy;
use mel::benchkit::group;
use mel::runtime::Manifest;
use mel::scenario::{CloudletConfig, Scenario};
use mel::util::rng::Pcg64;
use mel::util::stats::Welford;
use mel::util::table::{fnum, Table};

fn main() {
    let seed = 42;

    // ---- 1. SAI start point ------------------------------------------------
    group("ablation 1: suggest-and-improve start point (pedestrian, T=30s)");
    let mut t = Table::new(&["K", "start eq.32", "steps", "start relaxed τ*", "steps", "start τ=1", "steps"]);
    for k in [10usize, 20, 50, 100] {
        let cfg = CloudletConfig::pedestrian(k);
        let s = Scenario::random_cloudlet(&cfg, seed);
        let p = s.problem(30.0);
        let tau32 = UbSaiAllocator::tau_start(&p).unwrap();
        let a32 = sai::improve(&p, tau32, 0.0, vec![], "x").unwrap();
        let relaxed = mel::alloc::relax::solve(&p).unwrap().tau;
        let arel = sai::improve(&p, relaxed, 0.0, vec![], "x").unwrap();
        let acold = sai::improve(&p, 1.0, 0.0, vec![], "x").unwrap();
        assert_eq!(a32.tau, arel.tau);
        assert_eq!(a32.tau, acold.tau);
        t.row(vec![
            k.to_string(),
            fnum(tau32, 1),
            a32.sai_steps.to_string(),
            fnum(relaxed, 1),
            arel.sai_steps.to_string(),
            "1".into(),
            acold.sai_steps.to_string(),
        ]);
    }
    print!("{}", t.render());
    println!("same optimum from every start; the relaxed start converges in O(1) steps.\n");

    // ---- 2. rounding strategy ----------------------------------------------
    group("ablation 2: batch rounding — proportional fill vs naive floor");
    let s = Scenario::random_cloudlet(&CloudletConfig::pedestrian(20), seed);
    let p = s.problem(30.0);
    let a = Policy::Analytical.allocator().allocate(&p).unwrap();
    // naive: floor the relaxed batches, dump the remainder on learner 0
    let mut naive: Vec<usize> = a.relaxed_batches.iter().map(|&x| x as usize).collect();
    let short: usize = p.total_samples - naive.iter().sum::<usize>();
    naive[0] += short;
    let naive_feasible = naive
        .iter()
        .zip(&p.coeffs)
        .all(|(&d, c)| c.time(a.tau as f64, d as f64) <= p.t_total + 1e-6);
    println!(
        "proportional fill: feasible at tau={} | naive floor+dump: {} (dumps {} extra \
         samples on learner 0 and {}; the shared SAI fill is required)\n",
        a.tau,
        if naive_feasible { "feasible (lucky draw)" } else { "INFEASIBLE" },
        short,
        if naive_feasible { "happens to fit" } else { "breaks its deadline" },
    );

    // ---- 3. fading ----------------------------------------------------------
    group("ablation 3: static channels vs per-cycle Rayleigh + 3dB shadowing");
    for fading in [false, true] {
        let mut cfg = CloudletConfig::pedestrian(20);
        cfg.channel.rayleigh = fading;
        cfg.channel.shadow_sigma_db = if fading { 3.0 } else { 0.0 };
        let mut s = Scenario::random_cloudlet(&cfg, seed);
        let mut rng = Pcg64::seeded(7);
        let mut w_ada = Welford::new();
        let mut w_eta = Welford::new();
        for _ in 0..40 {
            if fading {
                s.redraw_fading(&cfg.channel, &mut rng);
            }
            let p = s.problem(30.0);
            w_ada.push(Policy::UbSai.allocator().allocate(&p).map(|a| a.tau).unwrap_or(0) as f64);
            w_eta.push(Policy::Eta.allocator().allocate(&p).map(|a| a.tau).unwrap_or(0) as f64);
        }
        println!(
            "{}: adaptive τ {:.1} ± {:.1}, ETA τ {:.1} ± {:.1}, gap {:.1}x",
            if fading { "fading " } else { "static " },
            w_ada.mean(),
            w_ada.std(),
            w_eta.mean(),
            w_eta.std(),
            w_ada.mean() / w_eta.mean().max(1.0)
        );
    }
    println!("the adaptive gain persists under per-cycle fading (re-solve each cycle).\n");

    // ---- 4. bucket set -------------------------------------------------------
    group("ablation 4: runtime bucket set vs padding waste");
    if let Ok(man) = Manifest::load("artifacts") {
        let mut t = Table::new(&["batch", "plan {64,128,256}", "pad", "plan {256}", "pad"]);
        for n in [40usize, 200, 500, 1000] {
            let ped = mel::models::ModelSpec::pedestrian();
            let plan = mel::coordinator::chunk_plan(&man, &ped.name, "grad_step", &ped.layers, n);
            let padded: usize = plan.iter().map(|(lo, hi, b)| b - (hi - lo)).sum();
            let only256 = (n + 255) / 256 * 256 - n;
            t.row(vec![
                n.to_string(),
                format!("{} chunks", plan.len()),
                padded.to_string(),
                format!("{} chunks", (n + 255) / 256),
                only256.to_string(),
            ]);
        }
        print!("{}", t.render());
        println!("multi-bucket plans cut tail padding by up to 4x for small batches.");
    } else {
        println!("artifacts not built; skipping bucket ablation (run `make artifacts`)");
    }
}
