//! Sharded cluster bench: full cluster runs (thread-per-shard event
//! queues + hierarchical aggregation) over a shards × learners sweep,
//! plus the churn-aware paths (membership re-splits and straggler
//! re-leasing under deadline pressure). Emits
//! `results/BENCH_cluster_cycle.json` via `benchkit::Suite` so the
//! perf trajectory tracks the cluster layer across PRs.
//!
//! ```bash
//! cargo bench --bench cluster_cycle
//! ```

use mel::benchkit::{group, Bencher, Suite};
use mel::cluster::{Cluster, ClusterConfig};
use mel::orchestrator::Mode;
use mel::prelude::*;

fn main() {
    let b = Bencher::quick();
    let seed = 42;
    let mut suite = Suite::new("cluster_cycle");

    group("churn-free cluster horizons (sync barrier per shard, 4 cycles)");
    for &(shards, k) in &[(1usize, 8usize), (2, 8), (4, 8), (4, 16), (8, 8)] {
        let cluster = Cluster::new(
            ClusterSpec::uniform("pedestrian", shards, k).expect("known task"),
            ClusterConfig {
                policy: Policy::Analytical,
                mode: Mode::Sync,
                t_total: 30.0,
                cycles: 4,
                seed,
                ..ClusterConfig::default()
            },
        );
        suite.run(&b, &format!("cluster sync: {shards} shard(s) x K={k}"), || {
            cluster.run().expect("feasible").updates_applied
        });
    }

    group("churn + straggler re-leasing (async, lease clock 24s of T=30s)");
    for &(shards, k) in &[(2usize, 8usize), (4, 8)] {
        let spec = ClusterSpec::uniform("pedestrian", shards, k)
            .expect("known task")
            .with_synthetic_churn(4.0 * 30.0, 2, seed);
        let cluster = Cluster::new(
            spec,
            ClusterConfig {
                policy: Policy::Analytical,
                mode: Mode::Async,
                t_total: 30.0,
                lease_s: 24.0,
                cycles: 4,
                straggler_releasing: true,
                seed,
                ..ClusterConfig::default()
            },
        );
        suite.run(&b, &format!("cluster churn+re-lease: {shards} shard(s) x K={k}"), || {
            cluster.run().expect("feasible").updates_applied
        });
    }

    group("cluster-level parameter server (2-shard per-update SGD replay)");
    {
        use mel::cluster::{ParamServer, ParamServerConfig};
        use mel::scenario::{ChurnTrace, ShardSpec};
        let mut cloudlet = CloudletConfig::pedestrian(2);
        cloudlet.model = cloudlet.model.with_hidden(&[8]);
        cloudlet.dataset.total_samples = 64;
        let spec = ClusterSpec {
            shards: (0..2)
                .map(|i| ShardSpec {
                    cloudlet: cloudlet.clone(),
                    seed_offset: i,
                    churn: ChurnTrace::default(),
                    population: None,
                })
                .collect(),
            global: Default::default(),
        };
        let cluster = Cluster::new(
            spec.clone(),
            ClusterConfig {
                policy: Policy::Analytical,
                mode: Mode::Sync,
                t_total: 2.0,
                cycles: 2,
                seed,
                ..ClusterConfig::default()
            },
        );
        let report = cluster.run().expect("feasible");
        // construction (engine spawn + dataset synthesis) stays outside
        // the timed closure: the stored-baseline CI gate watches the
        // replay path, not thread-startup jitter. Repeated replays on
        // one server do identical compute (same leases, same batch
        // sizes), so the per-iteration cost is stable.
        let mut ps = ParamServer::new(
            &spec,
            ParamServerConfig { lr: 0.05, seed, eval_samples: 32, ..Default::default() },
        )
        .expect("native engine");
        suite.run(&b, "param-server replay: 2 shards x K=2, 2 cycles (native)", || {
            ps.replay(&report.updates).expect("replay").applies
        });
    }

    group("churn-aware planner in isolation (K=16 re-split)");
    {
        use mel::cluster::ChurnAwarePlanner;
        let scenario = Scenario::random_cloudlet(&CloudletConfig::pedestrian(16), seed);
        let problem = scenario.problem(30.0);
        let mut flip = false;
        let mut planner = ChurnAwarePlanner::new(Policy::Analytical, vec![true; 16]);
        planner.plan_round(&problem, 0.0).expect("feasible");
        suite.run(&b, "membership toggle + full re-split (K=16)", || {
            flip = !flip;
            planner.on_membership(7, flip, &problem, 1.0);
            planner.planned_batches().iter().sum::<usize>()
        });
    }

    suite.write_and_report();
}
