//! Bench/repro target for **Fig. 2**: pedestrian dataset, τ vs global
//! cycle clock T for K = 5, 10, 20.
//!
//! ```bash
//! cargo bench --bench fig2_pedestrian_vs_t
//! ```

use mel::alloc::Policy;
use mel::benchkit::{group, Bencher, Suite};
use mel::experiments;
use mel::scenario::{CloudletConfig, Scenario};

fn main() {
    let seed = 42;
    group("Fig. 2 — pedestrian: tau vs T (K = 5, 10, 20)");
    let data = experiments::fig2(seed);
    print!("{}", data.table().render());

    let ana = data.series_by_prefix("UB-Analytical K=20").unwrap();
    let eta = data.series_by_prefix("ETA K=20").unwrap();
    // paper: at T=20s adaptive ≈ 4.2x ETA; at T=60s adaptive@20s ≥ ETA@60s
    let i20 = data.x.iter().position(|&t| t == 20.0).unwrap();
    let i60 = data.x.iter().position(|&t| t == 60.0).unwrap();
    println!(
        "anchor K=20: T=20s ETA {} vs adaptive {} (gain {:.1}x, paper ~4.2x); \
         adaptive@20s {} ≥ ETA@60s {} → {}\n",
        eta[i20],
        ana[i20],
        ana[i20] as f64 / eta[i20].max(1) as f64,
        ana[i20],
        eta[i60],
        ana[i20] >= eta[i60]
    );

    group("solve-time per (T, policy) point, K=20");
    let b = Bencher::default();
    let mut suite = Suite::new("fig2_pedestrian_vs_t");
    let scenario = Scenario::random_cloudlet(&CloudletConfig::pedestrian(20), seed);
    for &t in &[20.0f64, 60.0, 120.0] {
        let problem = scenario.problem(t);
        for policy in Policy::all() {
            let alloc = policy.allocator();
            suite.run(&b, &format!("fig2 T={t} {}", policy.label()), || {
                alloc.allocate(&problem).unwrap().tau
            });
        }
    }
    suite.write_and_report();
}
