//! Event-engine bench: binary-heap vs hierarchical timer-wheel
//! [`EventQueue`] throughput on fill/drain and steady-state workloads
//! up to 10^6 events, plus `plan_round`-style allocation latency flat
//! vs grouped on population-sampled pools (the sublinear fast path).
//! Emits `results/BENCH_sim_events.json` via `benchkit::Suite` so the
//! scaling trajectory of the event engine is CI-gated across PRs.
//!
//! ```bash
//! cargo bench --bench sim_events
//! ```

use mel::benchkit::{group, Bencher, Suite};
use mel::prelude::*;
use mel::scenario::PopulationSpec;
use mel::sim::events::EventQueue;
use mel::util::rng::Rng;

/// Fill a queue with `n` uniformly-timed events, then drain it dry —
/// the worst case for the heap (every pop pays the full log n
/// sift-down) and the bulk-advance case for the wheel.
fn fill_drain(mut q: EventQueue<u32>, n: usize, seed: u64) -> f64 {
    let mut rng = Pcg64::new(seed, 0x51E);
    for i in 0..n {
        q.schedule(rng.uniform(0.0, 3600.0), i as u32);
    }
    let mut last = 0.0;
    while let Some((t, _)) = q.pop() {
        last = t;
    }
    last
}

/// Steady-state simulator loop: a resident set of `k` pending leases,
/// `steps` pop-then-reschedule operations with exponential
/// inter-arrivals — the access pattern of the orchestrator's event
/// core under churn.
fn steady_state(mut q: EventQueue<u32>, k: usize, steps: usize, seed: u64) -> f64 {
    let mut rng = Pcg64::new(seed, 0x57D);
    for i in 0..k {
        q.schedule(rng.uniform(0.0, 30.0), i as u32);
    }
    let mut last = 0.0;
    for _ in 0..steps {
        let (t, e) = q.pop().expect("resident set never empties");
        last = t;
        q.schedule_in(rng.exponential(1.0 / 30.0), e);
    }
    last
}

fn main() {
    let b = Bencher::quick();
    let seed = 42;
    let mut suite = Suite::new("sim_events");

    group("fill/drain: schedule N then pop to empty (heap vs wheel)");
    for &n in &[1_000usize, 10_000, 100_000, 1_000_000] {
        suite.run(&b, &format!("events heap fill/drain: N={n}"), || {
            fill_drain(EventQueue::heap(), n, seed)
        });
        suite.run(&b, &format!("events wheel fill/drain: N={n}"), || {
            fill_drain(EventQueue::wheel(), n, seed)
        });
    }

    group("steady state: 10^4 resident leases, 10^5 pop+reschedule ops");
    {
        let (k, steps) = (10_000usize, 100_000usize);
        suite.run(&b, &format!("events heap steady: K={k} ops={steps}"), || {
            steady_state(EventQueue::heap(), k, steps, seed)
        });
        suite.run(&b, &format!("events wheel steady: K={k} ops={steps}"), || {
            steady_state(EventQueue::wheel(), k, steps, seed)
        });
    }

    group("allocation latency: flat per-learner vs grouped per-group solve");
    {
        let cloudlet = CloudletConfig::by_task("pedestrian", 64).expect("known task");
        let population = PopulationSpec::sample(&cloudlet, 16, seed);
        for &k in &[1_000usize, 10_000, 100_000] {
            let pop = population.rescaled(k);
            let gp = pop.grouped_problem(30.0);
            let flat = pop.expand().problem(30.0);
            suite.run(&b, &format!("plan flat UB-Analytical: K={k}"), || {
                Policy::Analytical.allocator().allocate(&flat).expect("feasible").tau
            });
            suite.run(&b, &format!("plan grouped UB-Analytical: K={k} G=16"), || {
                mel::alloc::grouped::solve_analytical(&gp).expect("feasible").tau
            });
        }
    }

    suite.write_and_report();
}
