//! Bench/repro target for **Fig. 3**: MNIST DNN [784,300,124,60,10].
//! (a) τ vs K for T = 30, 60 s; (b) τ vs T for K = 10, 20 — plus the
//! §V-C headline anchor (K=10, T=120 s: ETA 3 vs adaptive 12).
//!
//! ```bash
//! cargo bench --bench fig3_mnist
//! ```

use mel::alloc::Policy;
use mel::benchkit::{group, Bencher, Suite};
use mel::experiments;
use mel::scenario::{CloudletConfig, Scenario};

fn main() {
    let seed = 42;
    group("Fig. 3a — MNIST: tau vs K (T = 30, 60 s)");
    print!("{}", experiments::fig3a(seed).table().render());

    group("Fig. 3b — MNIST: tau vs T (K = 10, 20)");
    let data = experiments::fig3b(seed);
    print!("{}", data.table().render());

    let eta = experiments::solve_point("mnist", 10, 120.0, Policy::Eta, seed);
    let ada = experiments::solve_point("mnist", 10, 120.0, Policy::Numerical, seed);
    println!(
        "anchor K=10 T=120s: ETA {eta} vs adaptive {ada} (paper: 3 vs 12) → gain {:.1}x (paper 4.0x)\n",
        ada as f64 / eta.max(1) as f64
    );

    group("solve-time per policy, MNIST K=20 T=60s");
    let b = Bencher::default();
    let mut suite = Suite::new("fig3_mnist");
    let scenario = Scenario::random_cloudlet(&CloudletConfig::mnist(20), seed);
    let problem = scenario.problem(60.0);
    for policy in Policy::all() {
        let alloc = policy.allocator();
        suite.run(&b, &format!("fig3 {}", policy.label()), || {
            alloc.allocate(&problem).unwrap().tau
        });
    }
    suite.write_and_report();
}
