//! Sharded multi-cloudlet MEL with node churn and straggler-aware
//! re-leasing.
//!
//! Runs one cluster of identical pedestrian cloudlets twice on the same
//! seeds — once with straggler re-leasing (late updates applied,
//! stragglers re-leased with geometrically shrunken batches) and once
//! with the drop-on-miss baseline — under *deadline pressure*: the
//! batch split is solved for the clock `T`, but lease deadlines use a
//! shorter clock, so planned leases straggle deterministically. Each
//! shard also follows a synthetic churn trace (mid-run departures +
//! rejoins, late joiners), which triggers a full re-split of the
//! dataset across the surviving members on every membership change.
//!
//! ```bash
//! cargo run --release --example cluster_mel
//! # options: -- --shards 4 --k 6 --t 30 --lease 24 --cycles 8 --churners 2 --seed 42
//! ```

use mel::cluster::{Cluster, ClusterConfig};
use mel::orchestrator::Mode;
use mel::prelude::*;
use mel::util::cli::Args;
use mel::util::table::{fnum, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let shards = args.get_usize("shards", 4);
    let k = args.get_usize("k", 6);
    let t_total = args.get_f64("t", 30.0);
    let lease_s = args.get_f64("lease", 0.8 * t_total);
    let cycles = args.get_usize("cycles", 8);
    let churners = args.get_usize("churners", 2);
    let seed = args.get_u64("seed", 42);
    let horizon = cycles as f64 * t_total;

    println!(
        "cluster MEL: {shards} shard(s) x K={k} pedestrian, solve clock T={t_total}s, \
         lease clock {lease_s}s, horizon {horizon}s, {churners} churning node(s)/shard\n"
    );

    let spec = || {
        ClusterSpec::uniform("pedestrian", shards, k)
            .expect("known task")
            .with_synthetic_churn(horizon, churners, seed)
    };
    let cfg = |releasing: bool| ClusterConfig {
        policy: Policy::Analytical,
        mode: Mode::Async,
        t_total,
        lease_s,
        cycles,
        straggler_releasing: releasing,
        seed,
        ..ClusterConfig::default()
    };

    let releasing = Cluster::new(spec(), cfg(true));
    let report = releasing.run()?;

    let mut table = Table::new(&[
        "shard", "updates", "misses", "re-leases", "joins", "departs", "re-splits",
    ]);
    for sr in &report.shards {
        table.row(vec![
            sr.shard.to_string(),
            sr.report.updates_applied.to_string(),
            sr.misses.to_string(),
            sr.releases.to_string(),
            sr.joins.to_string(),
            sr.departs.to_string(),
            sr.resplits.to_string(),
        ]);
    }
    print!("{}", table.render());

    println!(
        "\nre-leasing: {} updates applied cluster-wide ({} deadline misses absorbed, \
         {} shrunken re-leases)",
        report.updates_applied, report.deadline_misses, report.releases
    );
    let merged = releasing.metrics.series("updates_vs_simtime");
    if let (Some(first), Some(last)) = (merged.first(), merged.last()) {
        println!(
            "merged updates_vs_simtime: {} points, first at t={}s, total {} by t={}s",
            merged.len(),
            fnum(first.0, 1),
            last.1,
            fnum(last.0, 1)
        );
    }

    // ---- drop-on-miss baseline on the same seeds
    let baseline = Cluster::new(spec(), cfg(false)).run()?;
    println!(
        "\ndrop-on-miss baseline: {} updates applied ({} dropped at the deadline)",
        baseline.updates_applied, baseline.deadline_misses
    );
    let gain = report.updates_applied as f64 / baseline.updates_applied.max(1) as f64;
    println!(
        "straggler-aware re-leasing delivers {}x the applied updates under identical \
         churn and deadline pressure",
        fnum(gain, 2)
    );
    Ok(())
}
