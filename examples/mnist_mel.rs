//! MNIST MEL study (the paper's §V-C workload): the deep model
//! [784,300,124,60,10] over a 60,000-sample dataset.
//!
//! Reproduces the Fig-3 series, then runs the paper's K=10, T=120 s
//! headline point (ETA τ=3 vs adaptive τ=12) through the *discrete-event
//! simulator*, printing the cycle timeline that explains the difference.
//!
//! ```bash
//! cargo run --release --example mnist_mel [-- --seed 7]
//! ```

use mel::alloc::Policy;
use mel::experiments;
use mel::scenario::{CloudletConfig, Scenario};
use mel::sim::{CycleSim, Phase};
use mel::util::cli::Args;
use mel::util::table::{fnum, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let seed = args.get_u64("seed", 42);

    // ---- Fig 3a / 3b series ----------------------------------------------
    println!("{}", experiments::fig3a(seed).table().render());
    println!("{}", experiments::fig3b(seed).table().render());

    // ---- the §V-C headline point -----------------------------------------
    let scenario = Scenario::random_cloudlet(&CloudletConfig::mnist(10), seed);
    let problem = scenario.problem(120.0);
    println!("\nheadline point: MNIST, K=10, T=120s (paper: ETA 3 vs adaptive 12)\n");

    for policy in [Policy::Eta, Policy::Numerical] {
        let alloc = policy.allocator().allocate(&problem)?;
        let sim = CycleSim::from_problem(&problem);
        let report = sim.run_cycle(&alloc, true);

        println!(
            "{}: τ = {}, makespan = {:.1}s / {}s",
            policy.label(),
            alloc.tau,
            report.makespan,
            problem.t_total
        );
        // compress the timeline into per-learner phase summaries
        let mut t = Table::new(&["learner", "d_k", "send end", "last iter", "receive end", "idle s"]);
        for k in 0..scenario.k() {
            let send_end = report
                .timeline
                .iter()
                .find(|e| e.1 == k && e.2 == Phase::SendEnd)
                .map(|e| e.0)
                .unwrap_or(0.0);
            let last_iter = report
                .timeline
                .iter()
                .filter(|e| e.1 == k && matches!(e.2, Phase::IterationDone(_)))
                .map(|e| e.0)
                .fold(0.0, f64::max);
            let recv = report.completion[k];
            t.row(vec![
                k.to_string(),
                alloc.batches[k].to_string(),
                fnum(send_end, 1),
                fnum(last_iter, 1),
                fnum(recv, 1),
                fnum(problem.t_total - recv, 1),
            ]);
        }
        print!("{}", t.render());
        println!();
    }

    println!(
        "ETA parks the laptop-class nodes after ~1/7 of the cycle; the adaptive \
         allocation shifts ~6x more samples onto them so every learner finishes \
         within seconds of the deadline."
    );
    Ok(())
}
