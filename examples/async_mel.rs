//! Asynchronous MEL: staggered per-learner cycles under Rayleigh fading
//! through the event-driven orchestration core.
//!
//! **Async timing model vs eq. (12)/(13).** The paper's synchronous
//! orchestrator clocks *everyone* on one global cycle: learner `k`'s
//! round trip `t_k = C²_k·τ·d_k + C¹_k·d_k + C⁰_k` (eq. 13, the phase
//! sum of eq. 12) must fit the shared deadline `T`, and the whole pool
//! then idles at the barrier until `T` elapses — so one shared `τ` is
//! pinned by the *slowest* learner. Asynchronous MEL
//! (arXiv:1905.01656) keeps eq. (13) as the per-round-trip physics but
//! drops the barrier: each learner gets its own **lease** — batch
//! `d_k`, per-learner `τ_k = ⌊τ_max_k(d_k)⌋`, deadline `dispatch + T`
//! — and is handed a fresh lease the moment its upload lands. Cycles
//! stagger: learner `k`'s j-th upload happens at (approximately)
//! `j·t_k(τ_k, d_k)`, not at `j·T`, updates apply immediately
//! (FedAsync-style), and *staleness* — how many other updates landed
//! while `k` was computing — replaces the barrier as the consistency
//! metric.
//!
//! This example runs both modes on the same fading cloudlet and prints
//! the event timeline head, per-learner cadence/τ_k, staleness, and the
//! throughput comparison.
//!
//! ```bash
//! cargo run --release --example async_mel
//! # options: -- --k 6 --t 30 --cycles 6 --seed 7 [--no-fading]
//! ```

use mel::orchestrator::{LearnerEvent, Mode, Orchestrator, OrchestratorConfig};
use mel::prelude::*;
use mel::util::cli::Args;
use mel::util::table::{fnum, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let k = args.get_usize("k", 6);
    let t_total = args.get_f64("t", 30.0);
    let cycles = args.get_usize("cycles", 6);
    let seed = args.get_u64("seed", 7);
    let fading = !args.has_flag("no-fading");

    let mut cloudlet = CloudletConfig::pedestrian(k);
    cloudlet.async_mode.enabled = true;
    cloudlet.async_mode.lease_s = t_total;
    if fading {
        cloudlet.channel.rayleigh = true;
    }
    println!(
        "async MEL: K={k}, lease clock T={t_total}s, horizon {}s, Rayleigh fading: {}\n",
        cycles as f64 * t_total,
        if fading { "on (redrawn per dispatch)" } else { "off" }
    );

    // mode / lease clock / fading knobs come from the cloudlet config's
    // JSON-loadable `async` block
    let base_cfg =
        OrchestratorConfig::from_cloudlet(&cloudlet, Policy::Eta, t_total, cycles, seed);

    // ---- asynchronous run (staggered leases, traced timeline)
    let scenario = Scenario::random_cloudlet(&cloudlet, seed);
    let mut cfg = base_cfg.clone();
    cfg.mode = Mode::Async;
    cfg.trace = true;
    let mut orch = Orchestrator::new(scenario, cfg);
    let report = orch.run()?;

    println!("event timeline (first 24 events):");
    for (t, ev) in report.timeline.iter().take(24) {
        let tag = match ev {
            LearnerEvent::Dispatched { learner } => format!("dispatch  -> learner {learner}"),
            LearnerEvent::SendComplete { learner } => format!("send done -> learner {learner}"),
            LearnerEvent::IterationDone { learner, iter } => {
                format!("iter {iter:>4}  @ learner {learner}")
            }
            LearnerEvent::Uploaded { learner } => format!("UPLOAD    <- learner {learner}"),
            LearnerEvent::DeadlineMissed { learner } => {
                format!("MISSED    <- learner {learner}")
            }
            LearnerEvent::Joined { learner } => format!("JOINED    -> learner {learner}"),
            LearnerEvent::Departed { learner } => format!("DEPARTED  <- learner {learner}"),
        };
        println!("  t={t:>9.3}s  {tag}");
    }

    // ---- per-learner cadence: staggered deadlines visible as differing
    // upload counts and τ_k
    let mut table = Table::new(&["learner", "class", "updates", "min tau_k", "max tau_k", "mean staleness"]);
    for id in 0..orch.scenario.k() {
        let ups: Vec<_> = report
            .updates
            .iter()
            .filter(|u| u.learner == id && !u.missed_deadline)
            .collect();
        if ups.is_empty() {
            continue;
        }
        let taus: Vec<u64> = ups.iter().map(|u| u.tau).collect();
        let stale: f64 =
            ups.iter().map(|u| u.staleness as f64).sum::<f64>() / ups.len() as f64;
        table.row(vec![
            id.to_string(),
            orch.scenario.learners[id].class.clone(),
            ups.len().to_string(),
            taus.iter().min().unwrap().to_string(),
            taus.iter().max().unwrap().to_string(),
            fnum(stale, 1),
        ]);
    }
    print!("{}", table.render());
    println!(
        "\nasync: {} updates applied in {}s (mean staleness {})",
        report.updates_applied,
        report.horizon,
        fnum(orch.metrics.summary_mean("staleness").unwrap_or(0.0), 2)
    );

    // ---- synchronous reference on the same cloudlet and horizon
    let scenario = Scenario::random_cloudlet(&cloudlet, seed);
    let mut cfg = base_cfg;
    cfg.mode = Mode::Sync;
    let mut sync_orch = Orchestrator::new(scenario, cfg);
    let sync_report = sync_orch.run()?;
    let iters = |r: &mel::orchestrator::OrchestratorReport| -> u64 {
        r.updates.iter().filter(|u| !u.missed_deadline).map(|u| u.tau).sum()
    };
    println!(
        "sync barrier reference: {} updates, {} local iterations — async delivered \
         {} iterations ({}x) by letting each learner fill its own lease",
        sync_report.updates_applied,
        iters(&sync_report),
        iters(&report),
        fnum(iters(&report) as f64 / iters(&sync_report).max(1) as f64, 2),
    );
    Ok(())
}
