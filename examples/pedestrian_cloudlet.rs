//! Pedestrian-detection cloudlet study (the paper's §V-B workload):
//! sweep the cloudlet size and clock, print Fig-1/Fig-2-style series,
//! and drill into *why* adaptive wins — per-learner batch shares and
//! utilization for one representative scenario, plus channel-fading
//! robustness (an extension beyond the paper's static channels).
//!
//! ```bash
//! cargo run --release --example pedestrian_cloudlet [-- --seed 7]
//! ```

use mel::alloc::Policy;
use mel::experiments;
use mel::scenario::{CloudletConfig, Scenario};
use mel::sim::CycleSim;
use mel::util::cli::Args;
use mel::util::rng::Pcg64;
use mel::util::table::{fnum, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let seed = args.get_u64("seed", 42);

    // ---- Fig 1 / Fig 2 series ------------------------------------------
    println!("{}", experiments::fig1(seed).table().render());
    println!("{}", experiments::fig2(seed).table().render());

    // ---- anatomy of one decision ----------------------------------------
    let scenario = Scenario::random_cloudlet(&CloudletConfig::pedestrian(8), seed);
    let problem = scenario.problem(30.0);
    let ada = Policy::Analytical.allocator().allocate(&problem)?;
    let eta = Policy::Eta.allocator().allocate(&problem)?;
    let sim = CycleSim::from_problem(&problem);
    let (u_ada, u_eta) = (sim.compute_utilization(&ada), sim.compute_utilization(&eta));

    let mut t = Table::new(&[
        "learner", "class", "dist(m)", "d_k (ETA)", "util% (ETA)", "d_k (adaptive)",
        "util% (adaptive)",
    ])
    .title("\nWhy adaptive wins: per-learner anatomy (K=8, T=30s)");
    for (k, l) in scenario.learners.iter().enumerate() {
        t.row(vec![
            k.to_string(),
            l.class.clone(),
            fnum(l.link.distance_m, 0),
            eta.batches[k].to_string(),
            fnum(100.0 * u_eta[k], 0),
            ada.batches[k].to_string(),
            fnum(100.0 * u_ada[k], 0),
        ]);
    }
    print!("{}", t.render());
    println!(
        "ETA leaves the laptops idle {}% of the cycle; adaptive fills them → τ {} vs {}.\n",
        fnum(100.0 * (1.0 - u_eta.iter().cloned().fold(1.0f64, f64::min)), 0),
        ada.tau,
        eta.tau
    );

    // ---- fading robustness (extension) -----------------------------------
    // Redraw Rayleigh fading each cycle and re-solve: how stable is τ?
    let mut cfg = CloudletConfig::pedestrian(10);
    cfg.channel.rayleigh = true;
    cfg.channel.shadow_sigma_db = 3.0;
    let mut s = Scenario::random_cloudlet(&cfg, seed);
    let mut rng = Pcg64::seeded(seed ^ 0xFAD);
    let mut taus = Vec::new();
    for _ in 0..30 {
        s.redraw_fading(&cfg.channel, &mut rng);
        let p = s.problem(30.0);
        taus.push(
            Policy::UbSai.allocator().allocate(&p).map(|a| a.tau).unwrap_or(0) as f64,
        );
    }
    let mut w = mel::util::stats::Welford::new();
    for &t in &taus {
        w.push(t);
    }
    println!(
        "Per-cycle re-allocation under Rayleigh+shadowing (30 cycles): \
         τ mean {:.1}, std {:.1}, min {:.0}, max {:.0}",
        w.mean(),
        w.std(),
        w.min(),
        w.max()
    );
    println!("(re-solving each cycle keeps every cycle feasible despite fading)");
    Ok(())
}
