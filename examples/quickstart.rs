//! Quickstart: build a 10-node heterogeneous cloudlet, solve the task
//! allocation with every policy, and inspect the decision.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use mel::alloc::Policy;
use mel::scenario::{CloudletConfig, Scenario};
use mel::sim::CycleSim;
use mel::util::table::{fnum, Table};

fn main() -> anyhow::Result<()> {
    // 1. A cloudlet: 10 nodes in a 50 m disc, half laptops, half RPis,
    //    802.11-style links (all Table I defaults).
    let scenario = Scenario::random_cloudlet(&CloudletConfig::pedestrian(10), 42);
    println!("cloudlet of K={} learners, task = {} ({} samples/cycle)\n",
        scenario.k(), scenario.model.name, scenario.dataset.total_samples);

    // 2. The allocation problem for a 30-second global cycle clock.
    let problem = scenario.problem(30.0);

    // 3. Solve with each policy and compare.
    let mut table = Table::new(&["policy", "tau", "min d_k", "max d_k", "mean util %"]);
    let sim = CycleSim::from_problem(&problem);
    for policy in Policy::all() {
        let alloc = policy.allocator().allocate(&problem)?;
        assert!(alloc.is_feasible(&problem));
        let util = sim.compute_utilization(&alloc);
        let mean_util = 100.0 * util.iter().sum::<f64>() / util.len() as f64;
        table.row(vec![
            policy.label().into(),
            alloc.tau.to_string(),
            alloc.batches.iter().min().unwrap().to_string(),
            alloc.batches.iter().max().unwrap().to_string(),
            fnum(mean_util, 1),
        ]);
    }
    print!("{}", table.render());

    // 4. The paper's point in one sentence:
    let eta = Policy::Eta.allocator().allocate(&problem)?;
    let ada = Policy::Analytical.allocator().allocate(&problem)?;
    println!(
        "\nAdaptive allocation fits {}x more local SGD iterations into the same \
         {}s cycle than equal allocation ({} vs {}).",
        ada.tau / eta.tau.max(1),
        problem.t_total,
        ada.tau,
        eta.tau
    );
    Ok(())
}
