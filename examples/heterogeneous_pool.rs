//! Heterogeneous-pool study — the paper's future-work axes exercised
//! together: **node selection** and **energy**.
//!
//! A cloudlet accumulates progressively worse stragglers (far-away,
//! underclocked IoT nodes). For each pool size we compare:
//! * naive all-in ETA (what [12]/[13] would do),
//! * ETA with greedy node triage (`alloc::selection::best_eta_subset`),
//! * adaptive allocation on the full pool (no triage needed — τ is
//!   monotone in enrolment),
//! and report τ, per-cycle energy, and energy per unit of learning work.
//!
//! ```bash
//! cargo run --release --example heterogeneous_pool [-- --seed 7]
//! ```

use mel::alloc::selection::{adaptive_full_pool, best_eta_subset, subproblem};
use mel::alloc::{eta::EtaAllocator, Policy, TaskAllocator as _};
use mel::channel::Link;
use mel::compute::ComputeProfile;
use mel::energy::{cycle_energy, DEFAULT_KAPPA};
use mel::learner::Learner;
use mel::scenario::{CloudletConfig, Scenario};
use mel::util::cli::Args;
use mel::util::table::{fnum, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let seed = args.get_u64("seed", 42);
    let t_total = args.get_f64("t", 30.0);

    // base cloudlet: 8 healthy nodes
    let mut scenario = Scenario::random_cloudlet(&CloudletConfig::pedestrian(8), seed);

    let mut table = Table::new(&[
        "stragglers",
        "ETA all-in tau",
        "ETA triaged tau (kept)",
        "adaptive tau",
        "adaptive J/cycle",
        "adaptive mJ/work",
    ]);

    for stragglers in 0..=4usize {
        if stragglers > 0 {
            // append one far, slow IoT node (100 m out, 200 MHz @ 0.25 fpc)
            let id = scenario.learners.len();
            scenario.learners.push(Learner::new(
                id,
                "iot-straggler",
                ComputeProfile::custom(200e6, 0.25),
                Link::at_distance(100.0),
            ));
        }
        let problem = scenario.problem(t_total);

        let eta_all = EtaAllocator.allocate(&problem).map(|a| a.tau).unwrap_or(0);
        let triage = best_eta_subset(&problem)?;
        let ada = adaptive_full_pool(&problem)?;
        let alloc = Policy::Analytical.allocator().allocate(&problem)?;
        let energy = cycle_energy(&scenario.learners, &scenario.model, &alloc, DEFAULT_KAPPA);

        table.row(vec![
            stragglers.to_string(),
            if eta_all == 0 { "infeasible".into() } else { eta_all.to_string() },
            format!("{} ({}/{})", triage.tau, triage.enrolled.len(), problem.k()),
            ada.tau.to_string(),
            fnum(energy.grand_total(), 1),
            fnum(1e3 * energy.joules_per_sample_iteration(&alloc), 3),
        ]);

        // invariant the module proves: triage never helps the adaptive policy
        let sub = subproblem(&problem, &triage.enrolled);
        let ada_triaged = Policy::Analytical.allocator().allocate(&sub)?;
        assert!(ada.tau >= ada_triaged.tau);
    }

    println!(
        "pool study: pedestrian task, T={t_total}s, 8 healthy nodes + N stragglers \
         (200 MHz IoT @ 100 m)\n"
    );
    print!("{}", table.render());
    println!(
        "\nETA needs node triage to survive stragglers; the adaptive allocator \
         absorbs them (monotone in enrolment) and even extracts a few extra \
         iterations from each straggler's spare capacity."
    );
    Ok(())
}
