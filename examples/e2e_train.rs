//! End-to-end MEL training driver — the full three-layer stack on a
//! real workload:
//!
//! * **L3** Rust orchestrator: adaptive allocation, simulated wireless
//!   cloudlet, thread fan-out, eq. (5) aggregation, metrics.
//! * **L2/L1** real compute: every local SGD iteration executes the
//!   JAX+Pallas `grad_step` artifact through PJRT.
//!
//! Trains the pedestrian classifier on a synthetic pedestrian-shaped
//! dataset under **the same simulated time budget** for the adaptive
//! (UB-Analytical) and ETA policies, and writes both loss curves —
//! the learning-accuracy-within-deadline story of the paper, measured
//! rather than argued.
//!
//! ```bash
//! cargo run --release --example e2e_train   # hermetic native backend
//! # (PJRT instead: make artifacts && rebuild with --features pjrt)
//! # options: -- --cycles 40 --k 4 --d 1024 --t 4 --lr 0.3 --out results/
//! ```

use mel::alloc::Policy;
use mel::coordinator::{Orchestrator, TrainConfig};
use mel::scenario::{CloudletConfig, Scenario};
use mel::util::cli::Args;
use mel::util::table::{fnum, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let k = args.get_usize("k", 4);
    let d = args.get_usize("d", 1024);
    let t_total = args.get_f64("t", 4.0);
    let cycles = args.get_usize("cycles", 30);
    let lr = args.get_f64("lr", 0.05) as f32;
    let seed = args.get_u64("seed", 42);
    let out_dir = args.get_str("out", "results").to_string();

    println!(
        "e2e MEL training: K={k} learners, d={d} samples/cycle, T={t_total}s, \
         {cycles} global cycles, lr={lr}\n"
    );

    let mut curves = Vec::new();
    let mut summary = Table::new(&[
        "policy", "tau", "final loss", "final acc", "cycles", "sim time", "wall compute",
    ]);

    for policy in [Policy::Analytical, Policy::Eta] {
        let mut scenario = Scenario::random_cloudlet(&CloudletConfig::pedestrian(k), seed);
        scenario.dataset.total_samples = d;
        let cfg = TrainConfig {
            policy,
            t_total,
            cycles,
            lr,
            seed,
            eval_samples: 512,
            artifact_dir: args.get_str("artifacts", "artifacts").to_string(),
            dispatch_threads: k,
            ..TrainConfig::default()
        };
        let mut orch = Orchestrator::new(scenario, cfg)?;
        let (loss0, acc0) = orch.evaluate()?;
        println!("[{}] initial loss {:.4}, accuracy {:.3}", policy.label(), loss0, acc0);
        let outcomes = orch.train()?;
        let last = outcomes.last().unwrap();
        let wall: f64 = outcomes.iter().map(|o| o.wall_compute_s).sum();
        println!(
            "[{}] τ={} per cycle → final loss {:.4}, accuracy {:.3} \
             (simulated {:.0}s, wall compute {:.1}s)\n",
            policy.label(),
            last.tau,
            last.loss,
            last.accuracy,
            orch.sim_time(),
            wall
        );
        summary.row(vec![
            policy.label().into(),
            last.tau.to_string(),
            fnum(last.loss, 4),
            fnum(last.accuracy, 3),
            outcomes.len().to_string(),
            format!("{:.0}s", orch.sim_time()),
            format!("{wall:.1}s"),
        ]);
        curves.push((policy.label(), orch.metrics.series("loss_vs_simtime")));
    }

    print!("{}", summary.render());

    // side-by-side loss curve table (same simulated-time grid)
    let mut curve_table = Table::new(&["sim time (s)", "loss (adaptive)", "loss (ETA)"])
        .title("\nloss vs simulated time — adaptive vs ETA under the same deadline budget");
    let (a, e) = (&curves[0].1, &curves[1].1);
    for i in 0..a.len().min(e.len()) {
        curve_table.row(vec![
            fnum(a[i].0, 0),
            fnum(a[i].1, 4),
            fnum(e[i].1, 4),
        ]);
    }
    print!("{}", curve_table.render());

    // verdict + persistence
    let (fa, fe) = (a.last().unwrap().1, e.last().unwrap().1);
    println!(
        "\nWithin the same simulated budget the adaptive policy reaches loss {:.4} \
         vs ETA {:.4} ({}).",
        fa,
        fe,
        if fa < fe { "adaptive wins — more local iterations per cycle" } else { "tie" }
    );
    std::fs::create_dir_all(&out_dir)?;
    let mut csv = String::from("sim_s,loss_adaptive,loss_eta\n");
    for i in 0..a.len().min(e.len()) {
        csv.push_str(&format!("{},{},{}\n", a[i].0, a[i].1, e[i].1));
    }
    let path = format!("{out_dir}/e2e_loss_curves.csv");
    std::fs::write(&path, csv)?;
    println!("wrote {path}");
    Ok(())
}
