//! Vendored, API-compatible subset of the `anyhow` crate so the
//! workspace builds with no registry access. Covers exactly what MELkit
//! uses: [`Error`], [`Result`], the [`anyhow!`] / [`ensure!`] / [`bail!`]
//! macros, `?`-conversion from any `std::error::Error`, and `Context`.
//!
//! The real crate keeps the source error chain alive; this subset
//! flattens it to the rendered message at conversion time, which is all
//! the MELkit call sites observe (they only `Display`/`Debug` errors).

use std::fmt;

/// A flattened dynamic error: the rendered message of whatever was
/// thrown. Deliberately does **not** implement `std::error::Error` so
/// the blanket `From<E: Error>` below never conflicts with the
/// reflexive `From<Error> for Error` the standard library provides.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg(msg: impl fmt::Display) -> Self {
        Self { msg: msg.to_string() }
    }

    /// The rendered message (parity helper with `anyhow::Error::root_cause`
    /// style interrogation — everything is flattened here).
    pub fn to_msg(&self) -> &str {
        &self.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `fn main() -> anyhow::Result<()>` prints the Debug form on
        // failure; render the message, as the real crate does.
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with a flattened error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error (subset: context is prepended to the
/// rendered message).
pub trait Context<T> {
    fn context(self, ctx: impl fmt::Display) -> Result<T>;
    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, ctx: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $(, $($arg:tt)*)?) => {
        $crate::Error::msg(format!($fmt $(, $($arg)*)?))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(&$err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*).into())
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $fmt:literal $(, $($arg:tt)*)?) => {
        if !($cond) {
            return Err($crate::anyhow!($fmt $(, $($arg)*)?).into());
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!("condition failed: `{}`", stringify!($cond)).into());
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn macros_format() {
        let x = 7;
        let e = anyhow!("value {x} bad");
        assert_eq!(e.to_string(), "value 7 bad");
        let e2 = anyhow!("value {} bad", 9);
        assert_eq!(e2.to_string(), "value 9 bad");

        fn guarded(v: i32) -> Result<i32> {
            ensure!(v > 0, "need positive, got {v}");
            Ok(v)
        }
        assert!(guarded(1).is_ok());
        assert!(guarded(-1).unwrap_err().to_string().contains("-1"));

        fn bailer() -> Result<()> {
            bail!("stop")
        }
        assert_eq!(bailer().unwrap_err().to_string(), "stop");
    }

    #[test]
    fn context_prepends() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("loading manifest").unwrap_err();
        assert!(e.to_string().starts_with("loading manifest: "));
    }
}
