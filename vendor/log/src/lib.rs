//! Vendored, API-compatible subset of the `log` facade so the workspace
//! builds with no registry access. Covers what MELkit uses: the five
//! level macros, [`Log`]/[`Record`]/[`Metadata`], `set_boxed_logger`,
//! `set_max_level`, `max_level`, and cross-type `Level`/`LevelFilter`
//! ordering.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity of one log record.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl Level {
    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `pad` so width/alignment specifiers like `{:5}` work.
        f.pad(self.as_str())
    }
}

/// A verbosity ceiling (adds `Off` below every level).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata about a record, consulted before the record is built.
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record.
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> fmt::Arguments<'a> {
        self.args
    }
}

/// A log sink.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

struct NopLogger;

impl Log for NopLogger {
    fn enabled(&self, _: &Metadata) -> bool {
        false
    }
    fn log(&self, _: &Record) {}
    fn flush(&self) {}
}

static LOGGER: OnceLock<Box<dyn Log>> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0);
static NOP: NopLogger = NopLogger;

/// Returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the global logger (first call wins).
pub fn set_boxed_logger(logger: Box<dyn Log>) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global verbosity ceiling.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// The global verbosity ceiling.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

/// The installed logger (no-op until [`set_boxed_logger`] succeeds).
pub fn logger() -> &'static dyn Log {
    match LOGGER.get() {
        Some(l) => l.as_ref(),
        None => &NOP,
    }
}

#[doc(hidden)]
pub fn __private_api_log(level: Level, target: &str, args: fmt::Arguments) {
    if level <= max_level() {
        let metadata = Metadata { level, target };
        let logger = logger();
        if logger.enabled(&metadata) {
            logger.log(&Record { metadata: Metadata { level, target }, args });
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__private_api_log($lvl, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    static SEEN: AtomicU64 = AtomicU64::new(0);

    struct Counter;

    impl Log for Counter {
        fn enabled(&self, m: &Metadata) -> bool {
            m.level() <= LevelFilter::Info
        }
        fn log(&self, r: &Record) {
            assert!(!r.target().is_empty());
            let _ = format!("{}", r.args());
            SEEN.fetch_add(1, Ordering::Relaxed);
        }
        fn flush(&self) {}
    }

    #[test]
    fn facade_filters_and_counts() {
        let _ = set_boxed_logger(Box::new(Counter));
        set_max_level(LevelFilter::Info);
        assert!(max_level() >= LevelFilter::Info);
        info!("hello {}", 1);
        debug!("filtered out {}", 2);
        assert_eq!(SEEN.load(Ordering::Relaxed), 1);
        // cross-type ordering
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Trace > LevelFilter::Info);
    }
}
