"""AOT lowering: JAX/Pallas → HLO text artifacts for the Rust runtime.

Lowers ``grad_step`` and ``eval_batch`` for every (architecture × batch
bucket) to ``artifacts/<name>.hlo.txt`` plus a ``manifest.json`` the Rust
``runtime::ArtifactStore`` consumes (tensor order, shapes, dtypes).

Interchange is HLO **text**, not serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids that the crate-side XLA
(xla_extension 0.5.1) rejects; the text parser reassigns ids and
round-trips cleanly. Lowered with ``return_tuple=True`` — the Rust side
unwraps the tuple.

Run via ``make artifacts``:  ``cd python && python -m compile.aot --out-dir ../artifacts``
"""

import argparse
import hashlib
import json
import os
from typing import List

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Batch buckets lowered per architecture. The runtime picks the smallest
# bucket ≥ remaining chunk and pads with mask=0 rows; 64→256 keeps padding
# waste < 50% for any d_k ≥ 64 while bounding artifact count.
BUCKETS = (64, 128, 256)
FUNCTIONS = ("grad_step", "eval_batch")


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _tensor_meta(shapes_dtypes) -> List[dict]:
    return [
        {"shape": list(map(int, s)), "dtype": str(d)} for (s, d) in shapes_dtypes
    ]


def _param_specs(layers):
    specs = []
    for (wshape, bshape) in model.layer_shapes(layers):
        specs.append(jax.ShapeDtypeStruct(wshape, jnp.float32))
        specs.append(jax.ShapeDtypeStruct(bshape, jnp.float32))
    return specs


def lower_artifact(arch: str, layers, fn_name: str, bucket: int):
    """Lower one (arch, fn, bucket) to HLO text; returns (text, meta)."""
    params = _param_specs(layers)
    x = jax.ShapeDtypeStruct((bucket, layers[0]), jnp.float32)
    y = jax.ShapeDtypeStruct((bucket,), jnp.int32)
    mask = jax.ShapeDtypeStruct((bucket,), jnp.float32)

    if fn_name == "grad_step":
        def fn(*args):
            p, (xx, yy, mm) = list(args[:-3]), args[-3:]
            return model.grad_step(p, xx, yy, mm)
        out_meta = [(p.shape, p.dtype) for p in params] + [((), "float32"), ((), "float32")]
    elif fn_name == "eval_batch":
        def fn(*args):
            p, (xx, yy, mm) = list(args[:-3]), args[-3:]
            return model.eval_batch(p, xx, yy, mm)
        out_meta = [((), "float32"), ((), "float32"), ((), "float32")]
    else:
        raise ValueError(fn_name)

    lowered = jax.jit(fn).lower(*params, x, y, mask)
    text = to_hlo_text(lowered)
    meta = {
        "arch": arch,
        "layers": list(layers),
        "function": fn_name,
        "bucket": bucket,
        "inputs": _tensor_meta(
            [(p.shape, p.dtype) for p in params]
            + [(x.shape, x.dtype), (y.shape, y.dtype), (mask.shape, mask.dtype)]
        ),
        "outputs": _tensor_meta(out_meta),
        "param_tensors": len(params),
        "hidden_activation": model.HIDDEN_ACT,
    }
    return text, meta


def build(out_dir: str, archs=None, buckets=BUCKETS, functions=FUNCTIONS) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    archs = archs or list(model.ARCHS)
    manifest = {"format": 1, "artifacts": []}
    for arch in archs:
        layers = model.ARCHS[arch]
        for fn_name in functions:
            for bucket in buckets:
                name = f"{arch}_{fn_name}_b{bucket}"
                path = os.path.join(out_dir, f"{name}.hlo.txt")
                text, meta = lower_artifact(arch, layers, fn_name, bucket)
                with open(path, "w") as f:
                    f.write(text)
                meta["name"] = name
                meta["file"] = f"{name}.hlo.txt"
                meta["sha256"] = hashlib.sha256(text.encode()).hexdigest()
                manifest["artifacts"].append(meta)
                print(f"  wrote {path}  ({len(text) / 1e6:.2f} MB)")
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"  wrote {mpath} ({len(manifest['artifacts'])} artifacts)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--arch", action="append", help="subset of archs to build")
    ap.add_argument("--buckets", default=",".join(map(str, BUCKETS)))
    args = ap.parse_args()
    buckets = tuple(int(b) for b in args.buckets.split(","))
    build(args.out_dir, archs=args.arch, buckets=buckets)


if __name__ == "__main__":
    main()
