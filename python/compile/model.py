"""L2 — the MEL learner's compute graph in JAX, built on the L1 kernels.

The paper trains MLP classifiers (pedestrian: 648-300-2 single hidden
layer; MNIST: 784-300-124-60-10) with full-batch gradient steps on each
learner's allocated batch. This module defines the two functions the Rust
coordinator executes through PJRT:

* ``grad_step`` — masked *sum*-of-losses gradient on one batch bucket.
  Returns per-layer gradients plus (loss_sum, weight_sum). The coordinator
  accumulates chunk gradients over a learner's whole batch and applies the
  SGD update itself (Rust owns optimizer state, exactly as the paper's
  orchestrator owns **w**).
* ``eval_batch`` — masked (loss_sum, correct_count, weight_sum) for
  monitoring global loss/accuracy.

HLO is shape-static while the allocator hands every learner a different
d_k, so ``aot.py`` lowers each function at a small set of batch *buckets*;
the runtime pads the final chunk with mask=0 rows. Masking uses sum-form
losses so padding is exactly neutral.

Everything here runs only at build time (``make artifacts``); Python is
never on the request path.
"""

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels import dense as K
from .kernels import ref
from .kernels import softmax_ce as CE

__all__ = [
    "ARCHS",
    "layer_shapes",
    "param_count",
    "flops_per_sample",
    "init_params",
    "forward",
    "forward_ref",
    "loss_sum",
    "grad_step",
    "eval_batch",
    "sgd_apply",
]

# The two architectures the paper evaluates (Section V).
ARCHS = {
    # 18x36 pedestrian images, binary classifier, one 300-unit hidden layer.
    "pedestrian": [648, 300, 2],
    # MNIST deep model "[784, 300, 124, 60, 10]".
    "mnist": [784, 300, 124, 60, 10],
}

HIDDEN_ACT = "relu"


def layer_shapes(layers: Sequence[int]) -> List[Tuple[Tuple[int, int], Tuple[int]]]:
    """[(w_shape, b_shape)] per layer for an MLP with the given widths."""
    return [((layers[i], layers[i + 1]), (layers[i + 1],)) for i in range(len(layers) - 1)]


def param_count(layers: Sequence[int], include_bias: bool = True) -> int:
    """Number of scalar parameters (paper's S_m counts weights only)."""
    n = sum(layers[i] * layers[i + 1] for i in range(len(layers) - 1))
    if include_bias:
        n += sum(layers[1:])
    return n


def flops_per_sample(layers: Sequence[int]) -> int:
    """Fwd+bwd floating point ops per sample, paper's C_m convention.

    The paper cites 781,208 flops for the 648-300-2 model, which is
    ≈ 2 fwd-matmul costs (fwd 2·Σ n_i·n_{i+1}, bwd ≈ same again) plus
    small activation terms. We use exactly 4·Σ n_i·n_{i+1} + 2·Σ n_i
    which reproduces the paper's order (780,000 + O(10³) for pedestrian).
    """
    mac = sum(layers[i] * layers[i + 1] for i in range(len(layers) - 1))
    act = sum(layers)
    return 4 * mac + 2 * act


def init_params(layers: Sequence[int], seed: int = 0) -> List[jnp.ndarray]:
    """Glorot-uniform init, flattened [w0, b0, w1, b1, ...].

    Only used by python-side tests; the Rust coordinator owns the live
    parameters and initializes them with the same scheme (see
    rust/src/coordinator/params.rs).
    """
    key = jax.random.PRNGKey(seed)
    params: List[jnp.ndarray] = []
    for (wshape, bshape) in layer_shapes(layers):
        key, sub = jax.random.split(key)
        limit = (6.0 / (wshape[0] + wshape[1])) ** 0.5
        params.append(jax.random.uniform(sub, wshape, jnp.float32, -limit, limit))
        params.append(jnp.zeros(bshape, jnp.float32))
    return params


def _split_params(params: Sequence[jnp.ndarray]):
    assert len(params) % 2 == 0, "params must be [w, b] pairs"
    return [(params[2 * i], params[2 * i + 1]) for i in range(len(params) // 2)]


def forward(params: Sequence[jnp.ndarray], x: jnp.ndarray) -> jnp.ndarray:
    """Logits via the Pallas fused-dense kernels (hidden relu, last linear)."""
    pairs = _split_params(params)
    h = x
    for li, (w, b) in enumerate(pairs):
        act = "linear" if li == len(pairs) - 1 else HIDDEN_ACT
        h = K.dense(h, w, b, act)
    return h


def forward_ref(params: Sequence[jnp.ndarray], x: jnp.ndarray) -> jnp.ndarray:
    """Same network on the pure-jnp reference path (test oracle)."""
    pairs = _split_params(params)
    h = x
    for li, (w, b) in enumerate(pairs):
        act = "linear" if li == len(pairs) - 1 else HIDDEN_ACT
        h = ref.dense_ref(h, w, b, act)
    return h


def _masked_ce(logits: jnp.ndarray, y: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Sum over samples of mask_j · CE(logits_j, y_j); exact under padding."""
    logz = jax.nn.logsumexp(logits, axis=1)
    picked = jnp.take_along_axis(logits, y[:, None], axis=1)[:, 0]
    return jnp.sum(mask * (logz - picked))


def loss_sum(params, x, y, mask, *, use_ref: bool = False) -> jnp.ndarray:
    """Masked CE sum; Pallas path uses the fused softmax-CE kernel so the
    whole fwd+loss (and its VJP) lowers through L1."""
    if use_ref:
        return _masked_ce(forward_ref(params, x), y, mask)
    return CE.softmax_ce(forward(params, x), y, mask)


def grad_step(params, x, y, mask, *, use_ref: bool = False):
    """Sum-loss gradients + (loss_sum, weight_sum) for one batch bucket.

    `y` is int32 class ids; `mask` is f32 {0,1}. Gradients are of the
    *sum* of per-sample losses so the runtime can accumulate chunks of a
    learner's batch and normalize once by the total weight:
        w ← w − lr/Σmask · Σ_chunks grad_chunk      (eq. 4 at batch scale)
    """
    loss, grads = jax.value_and_grad(
        lambda p: loss_sum(p, x, y, mask, use_ref=use_ref)
    )(list(params))
    wsum = jnp.sum(mask)
    return tuple(grads) + (loss, wsum)


def eval_batch(params, x, y, mask, *, use_ref: bool = False):
    """(loss_sum, correct_count, weight_sum) on one masked bucket."""
    fwd = forward_ref if use_ref else forward
    logits = fwd(params, x)
    loss = _masked_ce(logits, y, mask)
    pred = jnp.argmax(logits, axis=1).astype(jnp.int32)
    correct = jnp.sum(mask * (pred == y).astype(jnp.float32))
    return loss, correct, jnp.sum(mask)


def sgd_apply(params, grads, lr: float, weight_sum):
    """Reference SGD update (the Rust runtime re-implements this natively)."""
    scale = lr / jnp.maximum(weight_sum, 1.0)
    return [p - scale * g for p, g in zip(params, grads)]
