"""L1 — fused masked softmax-cross-entropy Pallas kernel.

Computes, in one pass over a (bucket, C) logits tile resident in VMEM:

    loss_sum  = Σ_j mask_j · (logsumexp(z_j) − z_j[y_j])
    grad      = mask_j ⊙ (softmax(z_j) − onehot(y_j))     (d loss_sum/dz)

This is the loss head of the MEL learner's grad-step; fusing it avoids
materializing the (bucket, C) softmax in HBM between the logits matmul
and the backward pass. Class counts here are tiny (2–10), so the whole
row fits a VMEM lane; the grid is 1-D over row blocks.

Numerically stable: per-row max subtraction before exp. Differentiable
via jax.custom_vjp (backward reuses the fused gradient — no second
softmax). Validated against `ref.softmax_ce_ref` by hypothesis sweeps in
python/tests/test_softmax_ce.py.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["softmax_ce", "softmax_ce_with_grad"]

DEFAULT_BLOCK_ROWS = 512


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _softmax_ce_kernel(z_ref, y_ref, mask_ref, loss_ref, grad_ref):
    """One row-block: per-row stable CE + masked gradient tile."""
    z = z_ref[...].astype(jnp.float32)  # (bm, C)
    y = y_ref[...]  # (bm,)
    mask = mask_ref[...].astype(jnp.float32)  # (bm,)
    zmax = jnp.max(z, axis=1, keepdims=True)
    ez = jnp.exp(z - zmax)
    sez = jnp.sum(ez, axis=1, keepdims=True)
    logz = jnp.log(sez) + zmax  # (bm, 1) logsumexp
    c = z.shape[1]
    onehot = (y[:, None] == jnp.arange(c, dtype=y.dtype)[None, :]).astype(jnp.float32)
    picked = jnp.sum(z * onehot, axis=1, keepdims=True)
    per_row = (logz - picked)[:, 0] * mask
    loss_ref[...] = jnp.sum(per_row)[None]
    grad_ref[...] = (mask[:, None] * (ez / sez - onehot)).astype(grad_ref.dtype)


@partial(jax.jit, static_argnames=("block_rows", "interpret"))
def softmax_ce_with_grad(
    logits, labels, mask, *, block_rows: int = DEFAULT_BLOCK_ROWS, interpret: bool = True
):
    """Fused `(loss_sum, dloss/dlogits)` for masked softmax CE."""
    n, c = logits.shape
    assert labels.shape == (n,) and mask.shape == (n,)
    bm = min(block_rows, _round_up(n, 8))
    np_ = _round_up(n, bm)
    pad = np_ - n
    if pad:
        logits = jnp.pad(logits, ((0, pad), (0, 0)))
        labels = jnp.pad(labels, (0, pad))
        mask = jnp.pad(mask, (0, pad))  # zero mask ⇒ padded rows inert
    grid = (np_ // bm,)
    loss_parts, grad = pl.pallas_call(
        _softmax_ce_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, c), lambda i: (i, 0)),
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((bm, c), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((grid[0],), jnp.float32),
            jax.ShapeDtypeStruct((np_, c), logits.dtype),
        ],
        interpret=interpret,
    )(logits, labels, mask)
    return jnp.sum(loss_parts), grad[:n]


@partial(jax.custom_vjp, nondiff_argnums=())
def softmax_ce(logits, labels, mask):
    """Differentiable masked CE **sum** via the fused Pallas kernel."""
    loss, _ = softmax_ce_with_grad(logits, labels, mask)
    return loss


def _ce_fwd(logits, labels, mask):
    loss, grad = softmax_ce_with_grad(logits, labels, mask)
    return loss, grad


def _ce_bwd(grad_residual, g):
    # d(loss_sum)/dlogits precomputed by the fused kernel; labels/mask
    # are integer/constant inputs → zero cotangents.
    return (g * grad_residual, None, None)


softmax_ce.defvjp(_ce_fwd, _ce_bwd)
