"""L1 — Pallas kernels for the MEL learner hot path.

The compute hot-spot of a MEL local iteration is the dense fwd/bwd of the
paper's MLPs (pedestrian 648-300-2, MNIST 784-300-124-60-10). We express
it as tiled Pallas kernels:

* ``fused_dense`` — ``activate(x @ w + b)`` with the bias-add and
  activation fused into the matmul epilogue.
* ``matmul`` — plain tiled matmul, used by the custom backward pass
  (dx = gz @ w.T, dw = x.T @ gz).

Tiling / hardware adaptation (see DESIGN.md §Hardware-Adaptation): the
grid is (M/bm, N/bn); each grid step keeps an (bm, K) LHS tile, a (K, bn)
RHS tile and an (bm, bn) accumulator resident in VMEM. K is not tiled —
the paper's reduction dims (≤ 784) fit comfortably: worst-case VMEM
footprint at bm=bn=128, K=784 is (128·784 + 784·128 + 128·128)·4 B ≈
0.83 MiB, far below the ~16 MiB VMEM budget. Tiles are MXU-shaped
(multiples of 128 where the problem allows). On CPU we must lower with
``interpret=True`` (real TPU lowering emits Mosaic custom-calls the CPU
PJRT plugin cannot execute), so these kernels are *structurally* TPU
kernels validated numerically on CPU.

Autodiff: ``pallas_call`` has no VJP in interpret mode, so
``fused_dense`` carries a ``jax.custom_vjp`` whose backward pass is itself
built from the Pallas ``matmul`` kernel — the whole fwd/bwd path lowers to
Pallas, and the L2 model can just ``jax.grad`` through it.
"""

import functools
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

__all__ = ["fused_dense", "matmul", "dense", "DEFAULT_BLOCK_M", "DEFAULT_BLOCK_N"]

# MXU-shaped default tiles (multiples of the 128×128 systolic array).
#
# Perf note (EXPERIMENTS.md §Perf/L1): 128×128 tiles keep VMEM minimal
# but serialize the interpret-mode grid loop (e.g. the 648×256·256×300
# dW matmul becomes an 18-step sequential grid). 512×512 tiles still fit
# the VMEM budget with slack — worst case here is
# (512·784 + 784·512 + 512·512)·4 B ≈ 3.3 MiB of the ~16 MiB budget —
# while collapsing most grids to a single step: measured 1.9× faster
# grad_step at bucket 256 on the CPU-interpret path, and structurally
# better MXU occupancy (fewer, larger systolic passes) on real TPU.
DEFAULT_BLOCK_M = 512
DEFAULT_BLOCK_N = 512


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pad2(a, rows: int, cols: int):
    """Zero-pad a 2-D array up to (rows, cols)."""
    pr, pc = rows - a.shape[0], cols - a.shape[1]
    if pr == 0 and pc == 0:
        return a
    return jnp.pad(a, ((0, pr), (0, pc)))


# ---------------------------------------------------------------------------
# fused dense: activate(x @ w + b)
# ---------------------------------------------------------------------------


def _fused_dense_kernel(x_ref, w_ref, b_ref, o_ref, *, activation: str):
    """One (bm, bn) output tile: full-K contraction + bias + activation.

    x_ref: (bm, K) VMEM tile, w_ref: (K, bn), b_ref: (1, bn), o_ref: (bm, bn).
    The contraction accumulates in f32 regardless of input dtype (MXU
    accumulates in f32 for bf16 inputs; we mirror that numerically).
    """
    acc = jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    acc = acc + b_ref[...].astype(jnp.float32)
    o_ref[...] = ref.activate(acc, activation).astype(o_ref.dtype)


@partial(jax.jit, static_argnames=("activation", "block_m", "block_n", "interpret"))
def fused_dense(
    x,
    w,
    b,
    activation: str = "linear",
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = True,
):
    """Fused dense layer ``activate(x @ w + b)`` as a tiled Pallas kernel.

    Inputs of any (M, K) x (K, N) shape are zero-padded up to the tile
    grid and the (M, N) result is sliced back out; zero-padding is exact
    for the matmul+bias (padded rows/cols produce garbage only in padded
    output slots that are discarded).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {x.shape} @ {w.shape}"
    assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"
    out_dtype = jnp.result_type(x.dtype, w.dtype)

    bm = min(block_m, _round_up(m, 8))
    bn = min(block_n, _round_up(n, 8))
    mp, np_ = _round_up(m, bm), _round_up(n, bn)

    xp = _pad2(x, mp, k)
    wp = _pad2(w, k, np_)
    bp = jnp.pad(b, (0, np_ - n)).reshape(1, np_)

    grid = (mp // bm, np_ // bn)
    out = pl.pallas_call(
        functools.partial(_fused_dense_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        interpret=interpret,
    )(xp, wp, bp)
    return out[:m, :n]


# ---------------------------------------------------------------------------
# plain matmul (backward-pass building block)
# ---------------------------------------------------------------------------


def _matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(
        a_ref[...].astype(jnp.float32),
        b_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


@partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def matmul(
    a,
    bmat,
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = True,
):
    """Tiled Pallas matmul ``a @ bmat`` with the same padding scheme."""
    m, k = a.shape
    k2, n = bmat.shape
    assert k == k2, f"contraction mismatch {a.shape} @ {bmat.shape}"
    out_dtype = jnp.result_type(a.dtype, bmat.dtype)

    bm = min(block_m, _round_up(m, 8))
    bn = min(block_n, _round_up(n, 8))
    mp, np_ = _round_up(m, bm), _round_up(n, bn)

    ap = _pad2(a, mp, k)
    bp = _pad2(bmat, k, np_)

    grid = (mp // bm, np_ // bn)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        interpret=interpret,
    )(ap, bp)
    return out[:m, :n]


# ---------------------------------------------------------------------------
# differentiable fused dense (custom VJP whose bwd is also Pallas)
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense(x, w, b, activation: str = "linear"):
    """Differentiable fused dense layer; fwd and bwd both run Pallas."""
    return fused_dense(x, w, b, activation)


def _dense_fwd(x, w, b, activation):
    # Recompute z in bwd from residuals (x, w, b): rematerialization keeps
    # the residual footprint at the inputs only — the same trade the paper's
    # memory-constrained edge devices would make.
    return fused_dense(x, w, b, activation), (x, w, b)


def _dense_bwd(activation, res, g):
    x, w, b = res
    # gz = g * act'(z); z recomputed with the fused kernel (linear epilogue).
    z = fused_dense(x, w, b, "linear")
    gz = (g * ref.activate_grad(z, activation)).astype(g.dtype)
    dx = matmul(gz, w.T)
    dw = matmul(x.T, gz)
    db = jnp.sum(gz, axis=0)
    return dx, dw.astype(w.dtype), db.astype(b.dtype)


dense.defvjp(_dense_fwd, _dense_bwd)
