"""Pure-jnp reference oracles for the Pallas kernels.

Every Pallas kernel in this package has an exact jnp twin here; pytest
(`python/tests/test_kernel.py`) sweeps shapes/dtypes with hypothesis and
asserts allclose between the two. These references are also what the L2
model's gradients are validated against.
"""

import jax.numpy as jnp

__all__ = [
    "activate",
    "activate_grad",
    "dense_ref",
    "matmul_ref",
    "dense_bwd_ref",
]


def activate(z, activation: str):
    """Apply the named activation. `linear` is identity."""
    if activation == "linear":
        return z
    if activation == "relu":
        return jnp.maximum(z, 0.0)
    if activation == "sigmoid":
        return 1.0 / (1.0 + jnp.exp(-z))
    if activation == "tanh":
        return jnp.tanh(z)
    raise ValueError(f"unknown activation {activation!r}")


def activate_grad(z, activation: str):
    """d activate(z) / d z, evaluated at pre-activation z."""
    if activation == "linear":
        return jnp.ones_like(z)
    if activation == "relu":
        return (z > 0.0).astype(z.dtype)
    if activation == "sigmoid":
        s = 1.0 / (1.0 + jnp.exp(-z))
        return s * (1.0 - s)
    if activation == "tanh":
        t = jnp.tanh(z)
        return 1.0 - t * t
    raise ValueError(f"unknown activation {activation!r}")


def dense_ref(x, w, b, activation: str = "linear"):
    """Reference fused dense layer: activate(x @ w + b)."""
    return activate(jnp.dot(x, w) + b[None, :], activation)


def matmul_ref(a, bmat):
    """Reference plain matmul (used by the dense backward pass)."""
    return jnp.dot(a, bmat)


def dense_bwd_ref(x, w, b, g, activation: str = "linear"):
    """Reference backward pass of the fused dense layer.

    Given upstream cotangent ``g`` (same shape as the layer output),
    returns ``(dx, dw, db)`` for output ``activate(x @ w + b)``.
    """
    z = jnp.dot(x, w) + b[None, :]
    gz = g * activate_grad(z, activation)
    dx = jnp.dot(gz, w.T)
    dw = jnp.dot(x.T, gz)
    db = jnp.sum(gz, axis=0)
    return dx, dw, db


def softmax_ce_ref(logits, labels, mask):
    """Reference masked softmax-CE sum (oracle for kernels.softmax_ce)."""
    import jax

    logz = jax.nn.logsumexp(logits, axis=1)
    picked = jnp.take_along_axis(logits, labels[:, None], axis=1)[:, 0]
    return jnp.sum(mask * (logz - picked))


def softmax_ce_grad_ref(logits, labels, mask):
    """Reference d(softmax_ce_ref)/dlogits."""
    import jax

    p = jax.nn.softmax(logits, axis=1)
    c = logits.shape[1]
    onehot = (labels[:, None] == jnp.arange(c)[None, :]).astype(logits.dtype)
    return mask[:, None] * (p - onehot)
