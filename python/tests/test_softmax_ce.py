"""L1 correctness: fused masked softmax-CE kernel vs jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import softmax_ce as S


def _case(rng, n, c, scale=3.0, mask_p=0.25):
    z = jnp.asarray(rng.normal(size=(n, c)) * scale, jnp.float32)
    y = jnp.asarray(rng.integers(0, c, size=(n,)), jnp.int32)
    m = jnp.asarray((rng.random(n) > mask_p).astype(np.float32))
    return z, y, m


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 300),
    c=st.integers(2, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_ce_matches_ref(n, c, seed):
    rng = np.random.default_rng(seed)
    z, y, m = _case(rng, n, c)
    loss, grad = S.softmax_ce_with_grad(z, y, m)
    np.testing.assert_allclose(
        float(loss), float(ref.softmax_ce_ref(z, y, m)), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(grad), np.asarray(ref.softmax_ce_grad_ref(z, y, m)), rtol=1e-4, atol=1e-6
    )


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 64), c=st.integers(2, 10), seed=st.integers(0, 2**31 - 1))
def test_fused_ce_vjp_matches_autodiff_of_ref(n, c, seed):
    rng = np.random.default_rng(seed)
    z, y, m = _case(rng, n, c)
    g_fused = jax.grad(lambda zz: S.softmax_ce(zz, y, m))(z)
    g_ref = jax.grad(lambda zz: ref.softmax_ce_ref(zz, y, m))(z)
    np.testing.assert_allclose(np.asarray(g_fused), np.asarray(g_ref), rtol=1e-4, atol=1e-6)


def test_numerical_stability_large_logits():
    """Stable under logits that overflow naive exp (row-max subtraction)."""
    z = jnp.asarray([[1000.0, 0.0], [-1000.0, -999.0], [500.0, 500.0]], jnp.float32)
    y = jnp.asarray([0, 1, 0], jnp.int32)
    m = jnp.ones((3,), jnp.float32)
    loss, grad = S.softmax_ce_with_grad(z, y, m)
    assert np.isfinite(float(loss))
    assert np.all(np.isfinite(np.asarray(grad)))
    # row 0: correct class dominates → ~0 loss; row 2: tie → ln 2
    per_row_expect = [0.0, np.log(1 + np.e ** -1), np.log(2.0)]
    np.testing.assert_allclose(float(loss), sum(per_row_expect), rtol=1e-4, atol=1e-4)


def test_masked_rows_contribute_nothing():
    rng = np.random.default_rng(1)
    z, y, _ = _case(rng, 20, 5)
    m_half = jnp.asarray([1.0] * 10 + [0.0] * 10, jnp.float32)
    loss_half, grad_half = S.softmax_ce_with_grad(z, y, m_half)
    loss_first, _ = S.softmax_ce_with_grad(z[:10], y[:10], jnp.ones((10,), jnp.float32))
    np.testing.assert_allclose(float(loss_half), float(loss_first), rtol=1e-5)
    assert np.all(np.asarray(grad_half)[10:] == 0.0)


@pytest.mark.parametrize("block_rows", [8, 16, 64])
def test_block_invariance(block_rows):
    rng = np.random.default_rng(2)
    z, y, m = _case(rng, 50, 4)
    base = ref.softmax_ce_ref(z, y, m)
    loss, _ = S.softmax_ce_with_grad(z, y, m, block_rows=block_rows)
    np.testing.assert_allclose(float(loss), float(base), rtol=1e-5)


def test_model_grad_step_still_matches_ref_with_fused_loss():
    """End-to-end: model.grad_step (now fused-CE) == jnp reference path."""
    from compile import model

    rng = np.random.default_rng(3)
    layers = [12, 9, 4]
    params = model.init_params(layers, 5)
    x = jnp.asarray(rng.normal(size=(21, 12)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 4, size=(21,)), jnp.int32)
    m = jnp.asarray((rng.random(21) > 0.3).astype(np.float32))
    outs_p = model.grad_step(params, x, y, m)
    outs_r = model.grad_step(params, x, y, m, use_ref=True)
    for a, b in zip(outs_p, outs_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-4, atol=1e-5)
