"""L2 correctness: model fwd/bwd on the Pallas path vs the jnp path,
masking neutrality, numerical-gradient checks, and the paper's model
constants (S_m, C_m conventions from Section V)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model


def _batch(rng, n, feat, classes):
    x = jnp.asarray(rng.normal(size=(n, feat)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, classes, size=(n,)).astype(np.int32))
    return x, y


# ---------------------------------------------------------------------------
# paper constants
# ---------------------------------------------------------------------------


def test_pedestrian_model_size_matches_paper():
    """Paper: w1 is 300x648, w2 is 300x2, model size 6,240,000 bits at Pm=32
    → S_m = 195,000 coefficients (weights only)."""
    layers = model.ARCHS["pedestrian"]
    assert layers == [648, 300, 2]
    assert model.param_count(layers, include_bias=False) == 195_000
    assert 32 * model.param_count(layers, include_bias=False) == 6_240_000


def test_pedestrian_flops_matches_paper_order():
    """Paper: 781,208 flops/sample; our 4·MAC + 2·act convention lands
    within 0.1% (the residual is the paper's unstated activation count)."""
    c = model.flops_per_sample(model.ARCHS["pedestrian"])
    assert abs(c - 781_208) / 781_208 < 1e-3


def test_mnist_arch_matches_paper():
    assert model.ARCHS["mnist"] == [784, 300, 124, 60, 10]
    # 784·300 + 300·124 + 124·60 + 60·10 = 280,440 weight coefficients.
    assert model.param_count(model.ARCHS["mnist"], include_bias=False) == 280_440


def test_layer_shapes_and_init():
    layers = [5, 4, 3]
    shapes = model.layer_shapes(layers)
    assert shapes == [((5, 4), (4,)), ((4, 3), (3,))]
    params = model.init_params(layers, seed=9)
    assert [p.shape for p in params] == [(5, 4), (4,), (4, 3), (3,)]
    # Glorot bound: |w| <= sqrt(6/(fan_in+fan_out))
    assert float(jnp.max(jnp.abs(params[0]))) <= (6.0 / 9.0) ** 0.5 + 1e-6
    assert float(jnp.max(jnp.abs(params[1]))) == 0.0


# ---------------------------------------------------------------------------
# pallas path == jnp path through the whole model
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 48),
    hidden=st.integers(1, 40),
    feat=st.integers(1, 50),
    classes=st.integers(2, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_grad_step_pallas_matches_ref(n, hidden, feat, classes, seed):
    rng = np.random.default_rng(seed)
    layers = [feat, hidden, classes]
    params = model.init_params(layers, seed % 1000)
    x, y = _batch(rng, n, feat, classes)
    mask = jnp.asarray((rng.random(n) > 0.3).astype(np.float32))
    outs_p = model.grad_step(params, x, y, mask)
    outs_r = model.grad_step(params, x, y, mask, use_ref=True)
    for a, b in zip(outs_p, outs_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-4, atol=1e-5)


def test_forward_deep_arch_matches_ref():
    rng = np.random.default_rng(0)
    layers = [20, 16, 12, 8, 5]  # MNIST-like depth
    params = model.init_params(layers, 3)
    x, _ = _batch(rng, 9, 20, 5)
    np.testing.assert_allclose(
        np.asarray(model.forward(params, x)),
        np.asarray(model.forward_ref(params, x)),
        rtol=5e-5,
        atol=1e-5,
    )


# ---------------------------------------------------------------------------
# masking: padded rows must be exactly neutral
# ---------------------------------------------------------------------------


def test_mask_padding_is_neutral():
    """grad_step on n real rows == grad_step on n real + p garbage rows
    with mask 0 — the property the Rust bucketed runtime relies on."""
    rng = np.random.default_rng(5)
    layers = [12, 10, 4]
    params = model.init_params(layers, 2)
    x, y = _batch(rng, 20, 12, 4)
    mask = jnp.ones((20,), jnp.float32)
    base = model.grad_step(params, x, y, mask)

    garbage_x = jnp.asarray(rng.normal(size=(12, 12)).astype(np.float32) * 100)
    garbage_y = jnp.asarray(rng.integers(0, 4, size=(12,)).astype(np.int32))
    xp = jnp.concatenate([x, garbage_x])
    yp = jnp.concatenate([y, garbage_y])
    mp = jnp.concatenate([mask, jnp.zeros((12,), jnp.float32)])
    padded = model.grad_step(params, xp, yp, mp)
    for a, b in zip(base, padded):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_eval_batch_mask_neutral_and_counts():
    rng = np.random.default_rng(6)
    layers = [8, 6, 3]
    params = model.init_params(layers, 4)
    x, y = _batch(rng, 10, 8, 3)
    mask = jnp.ones((10,), jnp.float32)
    loss, correct, wsum = model.eval_batch(params, x, y, mask)
    assert float(wsum) == 10.0
    assert 0.0 <= float(correct) <= 10.0
    # all-zero mask → all-zero stats
    z = model.eval_batch(params, x, y, jnp.zeros_like(mask))
    assert all(float(v) == 0.0 for v in z)


# ---------------------------------------------------------------------------
# gradient correctness: numerical finite differences
# ---------------------------------------------------------------------------


def test_grad_step_matches_finite_differences():
    rng = np.random.default_rng(8)
    layers = [6, 5, 3]
    params = model.init_params(layers, 7)
    x, y = _batch(rng, 7, 6, 3)
    mask = jnp.ones((7,), jnp.float32)
    outs = model.grad_step(params, x, y, mask)
    grads = outs[: len(params)]

    eps = 1e-3
    p0 = np.asarray(params[0]).copy()
    for (i, j) in [(0, 0), (3, 2), (5, 4)]:
        pp, pm = p0.copy(), p0.copy()
        pp[i, j] += eps
        pm[i, j] -= eps
        lp = float(model.loss_sum([jnp.asarray(pp)] + params[1:], x, y, mask, use_ref=True))
        lm = float(model.loss_sum([jnp.asarray(pm)] + params[1:], x, y, mask, use_ref=True))
        num = (lp - lm) / (2 * eps)
        assert abs(num - float(grads[0][i, j])) < 5e-3, (i, j)


def test_sgd_apply_descends():
    rng = np.random.default_rng(13)
    layers = [10, 8, 2]
    params = model.init_params(layers, 1)
    x, y = _batch(rng, 32, 10, 2)
    # learnable labels: y = sign of first feature
    y = (np.asarray(x)[:, 0] > 0).astype(np.int32)
    y = jnp.asarray(y)
    mask = jnp.ones((32,), jnp.float32)
    losses = []
    for _ in range(30):
        outs = model.grad_step(params, x, y, mask, use_ref=True)
        grads, loss, wsum = outs[:-2], outs[-2], outs[-1]
        losses.append(float(loss) / float(wsum))
        params = model.sgd_apply(params, grads, 0.5, wsum)
    assert losses[-1] < losses[0] * 0.7, losses[:3] + losses[-3:]
