"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes/activations; assert_allclose against
``ref.py``. This is the CORE correctness signal for the kernel layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import assume, given, settings, strategies as st

from compile.kernels import dense as K
from compile.kernels import ref

ACTS = ["linear", "relu", "sigmoid", "tanh"]


def _rand(rng, shape, dtype=np.float32):
    return jnp.asarray(rng.normal(size=shape).astype(dtype))


# ---------------------------------------------------------------------------
# fused_dense vs ref — hypothesis shape sweep
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 150),
    k=st.integers(1, 96),
    n=st.integers(1, 150),
    act=st.sampled_from(ACTS),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_dense_matches_ref(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _rand(rng, (m, k)), _rand(rng, (k, n)), _rand(rng, (n,))
    out = K.fused_dense(x, w, b, act)
    expect = ref.dense_ref(x, w, b, act)
    assert out.shape == (m, n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-5, atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 64),
    n=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a, b = _rand(rng, (m, k)), _rand(rng, (k, n))
    np.testing.assert_allclose(
        np.asarray(K.matmul(a, b)), np.asarray(ref.matmul_ref(a, b)), rtol=2e-5, atol=2e-5
    )


# ---------------------------------------------------------------------------
# tile-boundary / padding edge cases
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "m,n",
    [
        (128, 128),   # exactly one default tile
        (129, 127),   # one-off around the tile edge
        (256, 256),   # multi-tile grid
        (1, 1),       # degenerate
        (127, 257),   # mixed remainders
    ],
)
def test_fused_dense_tile_boundaries(m, n):
    rng = np.random.default_rng(m * 1000 + n)
    k = 33
    x, w, b = _rand(rng, (m, k)), _rand(rng, (k, n)), _rand(rng, (n,))
    out = K.fused_dense(x, w, b, "relu")
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.dense_ref(x, w, b, "relu")), rtol=2e-5, atol=2e-5
    )


@pytest.mark.parametrize("bm,bn", [(8, 8), (16, 128), (128, 16), (64, 64)])
def test_fused_dense_block_shape_invariance(bm, bn):
    """Output must not depend on the chosen tiling."""
    rng = np.random.default_rng(7)
    x, w, b = _rand(rng, (70, 30)), _rand(rng, (30, 50)), _rand(rng, (50,))
    base = ref.dense_ref(x, w, b, "tanh")
    out = K.fused_dense(x, w, b, "tanh", block_m=bm, block_n=bn)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base), rtol=2e-5, atol=2e-5)


def test_fused_dense_paper_shapes():
    """The exact layer shapes the paper's two models use."""
    rng = np.random.default_rng(42)
    for (m, k, n) in [(64, 648, 300), (64, 300, 2), (256, 784, 300), (256, 60, 10)]:
        x, w, b = _rand(rng, (m, k)), _rand(rng, (k, n)), _rand(rng, (n,))
        # Long contractions (K up to 784) accumulate order-dependent f32
        # noise ~ sqrt(K)·eps·|x||w|; tolerance scales accordingly.
        np.testing.assert_allclose(
            np.asarray(K.fused_dense(x, w, b, "relu")),
            np.asarray(ref.dense_ref(x, w, b, "relu")),
            rtol=1e-4,
            atol=1e-3,
        )


# ---------------------------------------------------------------------------
# dtype handling
# ---------------------------------------------------------------------------


def test_fused_dense_bfloat16_accumulates_in_f32():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(32, 128)), jnp.bfloat16)
    w = jnp.asarray(rng.normal(size=(128, 16)), jnp.bfloat16)
    b = jnp.asarray(rng.normal(size=(16,)), jnp.bfloat16)
    out = K.fused_dense(x, w, b, "linear")
    assert out.dtype == jnp.bfloat16
    expect = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)) + b.astype(jnp.float32)
    # bf16 storage: compare at bf16 resolution.
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), rtol=2e-2, atol=2e-2
    )


# ---------------------------------------------------------------------------
# custom-VJP gradients vs reference gradients
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 32),
    n=st.integers(1, 24),
    act=st.sampled_from(ACTS),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_vjp_matches_ref(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _rand(rng, (m, k)), _rand(rng, (k, n)), _rand(rng, (n,))
    if act == "relu":
        # relu's subgradient at 0 is ambiguous: a kernel-vs-ref z that
        # differs by 1 ulp flips the gate and produces an O(1) gradient
        # difference that is *correct* for both. Only compare away from
        # the kink.
        z = np.asarray(jnp.dot(x, w) + b[None, :])
        assume(np.abs(z).min() > 1e-3)
    # Smooth scalar head so grads are informative for every activation.
    def head(o):
        return jnp.sum(jnp.tanh(o) * 0.5)

    gp = jax.grad(lambda args: head(K.dense(*args, act)), argnums=0)((x, w, b))
    gr = jax.grad(lambda args: head(ref.dense_ref(*args, act)), argnums=0)((x, w, b))
    for a, e, name in zip(gp, gr, ["dx", "dw", "db"]):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(e), rtol=5e-4, atol=5e-5, err_msg=name
        )


def test_dense_bwd_ref_consistency():
    """ref.dense_bwd_ref agrees with jax.grad of the ref forward."""
    rng = np.random.default_rng(11)
    x, w, b = _rand(rng, (9, 7)), _rand(rng, (7, 5)), _rand(rng, (5,))
    g = _rand(rng, (9, 5))
    dx, dw, db = ref.dense_bwd_ref(x, w, b, g, "sigmoid")
    f = lambda x_, w_, b_: jnp.sum(ref.dense_ref(x_, w_, b_, "sigmoid") * g)
    ex = jax.grad(f, argnums=(0, 1, 2))(x, w, b)
    for a, e in zip((dx, dw, db), ex):
        np.testing.assert_allclose(np.asarray(a), np.asarray(e), rtol=1e-5, atol=1e-6)


def test_contraction_mismatch_raises():
    x = jnp.zeros((4, 5))
    w = jnp.zeros((6, 3))
    b = jnp.zeros((3,))
    with pytest.raises(AssertionError):
        K.fused_dense(x, w, b)
