"""AOT pipeline integrity: lowering produces parseable HLO text with the
manifest metadata the Rust runtime depends on."""

import hashlib
import json
import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def tiny_build(tmp_path_factory):
    """Build a miniature artifact set (tiny arch injected) once per module."""
    out = tmp_path_factory.mktemp("artifacts")
    model.ARCHS["tiny"] = [6, 5, 3]
    try:
        manifest = aot.build(str(out), archs=["tiny"], buckets=(8,))
    finally:
        del model.ARCHS["tiny"]
    return str(out), manifest


def test_manifest_structure(tiny_build):
    out, manifest = tiny_build
    assert manifest["format"] == 1
    arts = manifest["artifacts"]
    assert {a["function"] for a in arts} == {"grad_step", "eval_batch"}
    for a in arts:
        assert a["arch"] == "tiny"
        assert a["bucket"] == 8
        assert a["layers"] == [6, 5, 3]
        assert a["param_tensors"] == 4
        path = os.path.join(out, a["file"])
        assert os.path.exists(path)


def test_hlo_text_is_parseable_entry(tiny_build):
    out, manifest = tiny_build
    for a in manifest["artifacts"]:
        text = open(os.path.join(out, a["file"])).read()
        assert "HloModule" in text
        assert "ENTRY" in text
        # text/manifest integrity
        assert hashlib.sha256(text.encode()).hexdigest() == a["sha256"]


def test_manifest_io_shapes(tiny_build):
    _, manifest = tiny_build
    gs = next(a for a in manifest["artifacts"] if a["function"] == "grad_step")
    # inputs: w0 b0 w1 b1 x y mask
    shapes = [tuple(t["shape"]) for t in gs["inputs"]]
    assert shapes == [(6, 5), (5,), (5, 3), (3,), (8, 6), (8,), (8,)]
    dtypes = [t["dtype"] for t in gs["inputs"]]
    assert dtypes[-2] == "int32" and dtypes[-1] == "float32"
    # outputs: grads (same shapes as params) + loss_sum + weight_sum
    oshapes = [tuple(t["shape"]) for t in gs["outputs"]]
    assert oshapes == [(6, 5), (5,), (5, 3), (3,), (), ()]

    ev = next(a for a in manifest["artifacts"] if a["function"] == "eval_batch")
    assert [tuple(t["shape"]) for t in ev["outputs"]] == [(), (), ()]


def test_manifest_json_round_trips(tiny_build):
    out, manifest = tiny_build
    loaded = json.load(open(os.path.join(out, "manifest.json")))
    assert loaded == json.loads(json.dumps(manifest))


def test_default_buckets_are_sane():
    assert list(aot.BUCKETS) == sorted(set(aot.BUCKETS))
    assert all(b > 0 and b % 8 == 0 for b in aot.BUCKETS)
